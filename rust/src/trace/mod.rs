//! Deterministic request tracing on virtual clocks.
//!
//! A [`TraceCollector`] is a lock-light, bounded, per-lane span/event store
//! for the adaptive spine. Every record carries a *virtual* timestamp — the
//! pool batch clock on shard/dispatcher lanes, a per-collector wire tick on
//! the network lane, or simulated microseconds in offline `loadgen` runs —
//! never the wall clock (consistent with the `clippy.toml` ban), so a seeded
//! run produces the same trace every time.
//!
//! Lanes map to threads of the spine: lanes `0..n_shards` are the worker
//! shards, lane `n_shards` is the dispatcher, lane `n_shards + 1` is the
//! network front end. Each lane is an independently-locked bounded buffer,
//! so shards never contend with each other on the hot path; when a lane is
//! full new records are counted in `dropped` and discarded (conservation
//! gates require `dropped == 0`).
//!
//! The span taxonomy per request id follows the request's life:
//! `net.read → admission → dispatch.enqueue → queue.wait → shard.exec`
//! (with per-layer `kernel.layer` sub-spans) `→ net.write`, plus instant
//! events for steal, shed, brown-out, death, eager re-route, respawn, rung
//! up/down switches, and client retries. See `docs/observability.md` for
//! the full mapping onto Chrome trace-event JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Value;
use crate::metrics::Counter;

/// Typed span kinds, in request-lifecycle order. The discriminant order is
/// the canonical sort order inside one request's tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    NetRead,
    Admission,
    DispatchEnqueue,
    QueueWait,
    ShardExec,
    KernelLayer,
    NetWrite,
}

impl SpanKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::NetRead => "net.read",
            SpanKind::Admission => "admission",
            SpanKind::DispatchEnqueue => "dispatch.enqueue",
            SpanKind::QueueWait => "queue.wait",
            SpanKind::ShardExec => "shard.exec",
            SpanKind::KernelLayer => "kernel.layer",
            SpanKind::NetWrite => "net.write",
        }
    }
}

/// Typed instant-event kinds for the adaptivity mechanisms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    Steal,
    Shed,
    BrownOut,
    Death,
    Reroute,
    Respawn,
    RungUp,
    RungDown,
    ClientRetry,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Steal => "steal",
            EventKind::Shed => "shed",
            EventKind::BrownOut => "brown_out",
            EventKind::Death => "death",
            EventKind::Reroute => "reroute",
            EventKind::Respawn => "respawn",
            EventKind::RungUp => "rung_up",
            EventKind::RungDown => "rung_down",
            EventKind::ClientRetry => "client_retry",
        }
    }
}

/// One completed span: `[start, end]` on the recording lane's virtual clock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub req: u64,
    pub kind: SpanKind,
    pub lane: usize,
    pub start: u64,
    pub end: u64,
    /// Layer index for `kernel.layer` sub-spans; `None` otherwise.
    pub layer: Option<u32>,
    /// Free-form annotation (profile name, kernel op, deny code, ...).
    pub detail: String,
}

/// One instant event on a lane's virtual clock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    pub lane: usize,
    pub at: u64,
    /// Owning request id, when the event is request-scoped.
    pub req: Option<u64>,
    pub detail: String,
}

#[derive(Debug, Default)]
struct Lane {
    spans: Vec<Span>,
    events: Vec<Event>,
}

/// Correlation keys for requests denied before admission (they never get a
/// spine ticket id) are drawn from a disjoint key space above this offset,
/// so wire-side trees can never collide with spine request ids.
pub const DENIED_KEY_OFFSET: u64 = 1 << 48;

/// Default per-lane record bound (spans + events).
pub const DEFAULT_LANE_CAPACITY: usize = 1 << 20;

/// Bounded per-lane span/event collector. Cheap enough to leave plumbed in
/// release builds: the disabled path is `Option<&TraceCollector>` = `None`,
/// and the enabled path takes one short per-lane mutex per record.
#[derive(Debug)]
pub struct TraceCollector {
    lanes: Vec<Mutex<Lane>>,
    n_shards: usize,
    cap_per_lane: usize,
    dropped: Counter,
    wire_clock: AtomicU64,
    denied_keys: AtomicU64,
}

impl TraceCollector {
    /// A collector for `n_shards` worker lanes plus the dispatcher and
    /// network lanes.
    pub fn new(n_shards: usize) -> Self {
        TraceCollector::with_capacity(n_shards, DEFAULT_LANE_CAPACITY)
    }

    pub fn with_capacity(n_shards: usize, cap_per_lane: usize) -> Self {
        let n_shards = n_shards.max(1);
        TraceCollector {
            lanes: (0..n_shards + 2).map(|_| Mutex::new(Lane::default())).collect(),
            n_shards,
            cap_per_lane: cap_per_lane.max(1),
            dropped: Counter::default(),
            wire_clock: AtomicU64::new(0),
            denied_keys: AtomicU64::new(0),
        }
    }

    /// Lane index for worker shard `wid` (clamped defensively).
    pub fn shard_lane(&self, wid: usize) -> usize {
        wid.min(self.n_shards - 1)
    }

    /// Lane index for the dispatcher thread.
    pub fn dispatch_lane(&self) -> usize {
        self.n_shards
    }

    /// Lane index for the network front end.
    pub fn net_lane(&self) -> usize {
        self.n_shards + 1
    }

    /// Next tick of the network lane's virtual clock. The wire side has no
    /// batch clock, so it advances a private monotonic counter instead.
    pub fn next_wire_tick(&self) -> u64 {
        self.wire_clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Correlation key for a request denied before admission (no ticket id).
    pub fn denied_key(&self) -> u64 {
        DENIED_KEY_OFFSET + self.denied_keys.fetch_add(1, Ordering::Relaxed)
    }

    /// Records dropped because a lane hit its bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    fn lane(&self, lane: usize) -> &Mutex<Lane> {
        // Defensive clamp: a bad lane index must never panic the hot path.
        &self.lanes[lane.min(self.lanes.len() - 1)]
    }

    /// Record a completed span.
    pub fn span(&self, lane: usize, req: u64, kind: SpanKind, start: u64, end: u64) {
        self.span_full(lane, req, kind, start, end, None, String::new());
    }

    /// Record a completed span with a detail annotation.
    pub fn span_detail(
        &self,
        lane: usize,
        req: u64,
        kind: SpanKind,
        start: u64,
        end: u64,
        detail: impl Into<String>,
    ) {
        self.span_full(lane, req, kind, start, end, None, detail.into());
    }

    /// Record a per-layer `kernel.layer` sub-span of a `shard.exec` span.
    pub fn layer_span(
        &self,
        lane: usize,
        req: u64,
        layer: u32,
        op: &'static str,
        start: u64,
        end: u64,
    ) {
        self.span_full(
            lane,
            req,
            SpanKind::KernelLayer,
            start,
            end,
            Some(layer),
            op.to_string(),
        );
    }

    fn span_full(
        &self,
        lane: usize,
        req: u64,
        kind: SpanKind,
        start: u64,
        end: u64,
        layer: Option<u32>,
        detail: String,
    ) {
        let mut l = self.lane(lane).lock().unwrap();
        if l.spans.len() + l.events.len() >= self.cap_per_lane {
            self.dropped.inc();
            return;
        }
        l.spans.push(Span {
            req,
            kind,
            lane,
            start,
            end: end.max(start),
            layer,
            detail,
        });
    }

    /// Record an instant event.
    pub fn event(
        &self,
        lane: usize,
        kind: EventKind,
        at: u64,
        req: Option<u64>,
        detail: impl Into<String>,
    ) {
        let mut l = self.lane(lane).lock().unwrap();
        if l.spans.len() + l.events.len() >= self.cap_per_lane {
            self.dropped.inc();
            return;
        }
        l.events.push(Event {
            kind,
            lane,
            at,
            req,
            detail: detail.into(),
        });
    }

    /// Drain every lane into a canonically-sorted snapshot. The sort order
    /// depends only on record *contents* (never on arrival interleaving), so
    /// two runs that record the same set of spans/events snapshot — and
    /// serialize — identically.
    pub fn snapshot(&self) -> TraceSnapshot {
        let mut spans = Vec::new();
        let mut events = Vec::new();
        for lane in &self.lanes {
            let l = lane.lock().unwrap();
            spans.extend(l.spans.iter().cloned());
            events.extend(l.events.iter().cloned());
        }
        spans.sort_by(|a, b| {
            (a.req, a.kind, a.layer, a.lane, a.start, &a.detail)
                .cmp(&(b.req, b.kind, b.layer, b.lane, b.start, &b.detail))
        });
        events.sort_by(|a, b| {
            (a.at, a.kind, a.lane, a.req, &a.detail).cmp(&(b.at, b.kind, b.lane, b.req, &b.detail))
        });
        TraceSnapshot {
            spans,
            events,
            dropped: self.dropped(),
        }
    }
}

/// A canonically-sorted point-in-time copy of a collector's contents.
#[derive(Debug, Clone)]
pub struct TraceSnapshot {
    pub spans: Vec<Span>,
    pub events: Vec<Event>,
    pub dropped: u64,
}

impl TraceSnapshot {
    /// All spans belonging to one request id, in lifecycle order.
    pub fn spans_for(&self, req: u64) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.req == req).collect()
    }

    pub fn has_span(&self, req: u64, kind: SpanKind) -> bool {
        self.spans.iter().any(|s| s.req == req && s.kind == kind)
    }

    pub fn count_events(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// A served request's tree is complete when every lifecycle stage from
    /// the wire read to the wire write landed a span. (`dispatch.enqueue`
    /// is included: even an eagerly re-routed request was first enqueued.)
    pub fn served_tree_complete(&self, req: u64) -> bool {
        [
            SpanKind::NetRead,
            SpanKind::Admission,
            SpanKind::DispatchEnqueue,
            SpanKind::QueueWait,
            SpanKind::ShardExec,
            SpanKind::NetWrite,
        ]
        .iter()
        .all(|&k| self.has_span(req, k))
    }

    /// A denied (shed / bad-request / draining) request never reaches the
    /// spine; its tree is complete with the wire-side spans alone.
    pub fn denied_tree_complete(&self, req: u64) -> bool {
        [SpanKind::NetRead, SpanKind::Admission, SpanKind::NetWrite]
            .iter()
            .all(|&k| self.has_span(req, k))
    }

    /// Export as Chrome trace-event JSON (the Perfetto / `chrome://tracing`
    /// format). Virtual clock ticks are scaled to microsecond `ts` values
    /// (x1000 per tick) so distinct ticks render as distinct instants;
    /// `kernel.layer` sub-spans nest inside their tick at +`layer` offsets.
    /// Output is deterministic: the snapshot is canonically sorted and the
    /// JSON object keys are `BTreeMap`-ordered.
    pub fn to_chrome_json(&self) -> Value {
        const TICK_US: u64 = 1000;
        let mut rows: Vec<Value> = Vec::with_capacity(self.spans.len() + self.events.len());
        for s in &self.spans {
            let (name, ts, dur) = match s.layer {
                Some(layer) => (
                    format!("{}.{}.{}", s.kind.as_str(), layer, s.detail),
                    s.start * TICK_US + layer as u64,
                    1,
                ),
                None => (
                    s.kind.as_str().to_string(),
                    s.start * TICK_US,
                    ((s.end - s.start) * TICK_US).max(1),
                ),
            };
            let mut args = vec![("req", Value::Int(s.req as i64))];
            if s.layer.is_none() && !s.detail.is_empty() {
                args.push(("detail", Value::Str(s.detail.clone())));
            }
            rows.push(Value::obj(vec![
                ("name", Value::Str(name)),
                ("cat", Value::Str("span".to_string())),
                ("ph", Value::Str("X".to_string())),
                ("ts", Value::Int(ts as i64)),
                ("dur", Value::Int(dur as i64)),
                ("pid", Value::Int(0)),
                ("tid", Value::Int(s.lane as i64)),
                ("args", Value::obj(args)),
            ]));
        }
        for e in &self.events {
            let mut args = Vec::new();
            if let Some(req) = e.req {
                args.push(("req", Value::Int(req as i64)));
            }
            if !e.detail.is_empty() {
                args.push(("detail", Value::Str(e.detail.clone())));
            }
            rows.push(Value::obj(vec![
                ("name", Value::Str(e.kind.as_str().to_string())),
                ("cat", Value::Str("event".to_string())),
                ("ph", Value::Str("i".to_string())),
                ("s", Value::Str("t".to_string())),
                ("ts", Value::Int((e.at * TICK_US) as i64)),
                ("pid", Value::Int(0)),
                ("tid", Value::Int(e.lane as i64)),
                ("args", Value::obj(args)),
            ]));
        }
        Value::obj(vec![
            ("displayTimeUnit", Value::Str("ms".to_string())),
            ("traceEvents", Value::Array(rows)),
            (
                "metadata",
                Value::obj(vec![("dropped", Value::Int(self.dropped as i64))]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_map_shards_dispatcher_net() {
        let t = TraceCollector::new(4);
        assert_eq!(t.shard_lane(0), 0);
        assert_eq!(t.shard_lane(3), 3);
        assert_eq!(t.shard_lane(99), 3); // clamped
        assert_eq!(t.dispatch_lane(), 4);
        assert_eq!(t.net_lane(), 5);
    }

    #[test]
    fn snapshot_sorts_canonically_regardless_of_arrival_order() {
        let record = |order: &[usize]| {
            let t = TraceCollector::new(2);
            for &i in order {
                match i {
                    0 => t.span(0, 7, SpanKind::ShardExec, 3, 4),
                    1 => t.span(t.net_lane(), 7, SpanKind::NetRead, 0, 0),
                    2 => t.layer_span(0, 7, 1, "pool", 3, 4),
                    3 => t.layer_span(0, 7, 0, "conv", 3, 4),
                    _ => t.event(0, EventKind::Steal, 2, Some(7), "from 1"),
                }
            }
            t.snapshot()
        };
        let a = record(&[0, 1, 2, 3, 4]);
        let b = record(&[4, 3, 2, 1, 0]);
        assert_eq!(a.spans, b.spans);
        assert_eq!(a.events, b.events);
        assert_eq!(a.to_chrome_json().to_string(), b.to_chrome_json().to_string());
        // Lifecycle order within the request: net.read < shard.exec < layers.
        assert_eq!(a.spans[0].kind, SpanKind::NetRead);
        assert_eq!(a.spans[1].kind, SpanKind::ShardExec);
        assert_eq!(a.spans[2].layer, Some(0));
        assert_eq!(a.spans[3].layer, Some(1));
    }

    #[test]
    fn tree_completeness_checks() {
        let t = TraceCollector::new(1);
        let net = t.net_lane();
        t.span(net, 1, SpanKind::NetRead, 0, 0);
        t.span(net, 1, SpanKind::Admission, 0, 0);
        t.span(t.dispatch_lane(), 1, SpanKind::DispatchEnqueue, 0, 0);
        t.span(0, 1, SpanKind::QueueWait, 0, 1);
        t.span(0, 1, SpanKind::ShardExec, 1, 2);
        t.span(net, 1, SpanKind::NetWrite, 3, 3);
        let denied = t.denied_key();
        t.span(net, denied, SpanKind::NetRead, 4, 4);
        t.span(net, denied, SpanKind::Admission, 4, 4);
        t.event(net, EventKind::Shed, 4, Some(denied), "overloaded");
        t.span(net, denied, SpanKind::NetWrite, 4, 4);
        let snap = t.snapshot();
        assert!(snap.served_tree_complete(1));
        assert!(!snap.served_tree_complete(denied));
        assert!(snap.denied_tree_complete(denied));
        assert_eq!(snap.count_events(EventKind::Shed), 1);
        assert!(denied >= DENIED_KEY_OFFSET);
        assert_eq!(snap.dropped, 0);
    }

    #[test]
    fn bounded_lane_counts_drops() {
        let t = TraceCollector::with_capacity(1, 2);
        t.span(0, 1, SpanKind::ShardExec, 0, 1);
        t.event(0, EventKind::Steal, 1, None, "");
        t.span(0, 2, SpanKind::ShardExec, 1, 2); // over the bound
        t.event(0, EventKind::Steal, 2, None, ""); // over the bound
        let snap = t.snapshot();
        assert_eq!(snap.spans.len(), 1);
        assert_eq!(snap.events.len(), 1);
        assert_eq!(snap.dropped, 2);
        assert_eq!(t.dropped(), 2);
        // Other lanes are unaffected by lane 0 being full.
        t.span(t.net_lane(), 3, SpanKind::NetRead, 0, 0);
        assert_eq!(t.snapshot().spans.len(), 2);
    }

    #[test]
    fn wire_clock_and_denied_keys_are_monotonic() {
        let t = TraceCollector::new(1);
        assert_eq!(t.next_wire_tick(), 0);
        assert_eq!(t.next_wire_tick(), 1);
        let a = t.denied_key();
        let b = t.denied_key();
        assert_eq!(b, a + 1);
        assert!(a >= DENIED_KEY_OFFSET);
    }

    #[test]
    fn chrome_json_shape() {
        let t = TraceCollector::new(1);
        t.span_detail(0, 5, SpanKind::ShardExec, 2, 3, "hi");
        t.layer_span(0, 5, 0, "conv", 2, 3);
        t.event(0, EventKind::RungDown, 2, None, "hi -> lo");
        let j = t.snapshot().to_chrome_json();
        let rows = j.get("traceEvents").and_then(Value::as_array).unwrap();
        assert_eq!(rows.len(), 3);
        let exec = &rows[0];
        assert_eq!(exec.get("name").and_then(Value::as_str), Some("shard.exec"));
        assert_eq!(exec.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(exec.get("ts").and_then(Value::as_i64), Some(2000));
        assert_eq!(exec.get("dur").and_then(Value::as_i64), Some(1000));
        let layer = &rows[1];
        assert_eq!(
            layer.get("name").and_then(Value::as_str),
            Some("kernel.layer.0.conv")
        );
        let ev = &rows[2];
        assert_eq!(ev.get("ph").and_then(Value::as_str), Some("i"));
        assert_eq!(ev.get("name").and_then(Value::as_str), Some("rung_down"));
        let dropped = j.get("metadata").and_then(|m| m.get("dropped"));
        assert_eq!(dropped.and_then(Value::as_i64), Some(0));
    }
}
