//! MDC — Multi-Dataflow Composer (rust port of the paper's merging tool).
//!
//! The paper uses MDC to obtain *computation approximation*: several
//! data-approximated profiles of the same CNN are merged into one
//! coarse-grained-reconfigurable datapath. Actors that are identical across
//! profiles (same template, same hyper-parameters, same precision — and for
//! ROMs, same weights) are instantiated once and shared; where profiles
//! diverge, profile-specific actors are instantiated side by side and
//! switch boxes (SBoxes) steer the token stream according to the selected
//! configuration. Switching profile at runtime is a configuration-register
//! write — no re-synthesis, no reconfiguration latency (paper Sect. 4.4).
//!
//! * [`sig`]   — actor signatures: what "identical" means for sharing.
//! * [`merge`] — the merging algorithm + per-profile configurations.
//! * [`cost`]  — resource overhead of the merged engine (SBox muxes) and
//!   the `resource(merged) <= sum(resource(inputs))` accounting.

mod cost;
mod merge;
mod sig;

pub use cost::{merged_estimate, MergedCost};
pub use merge::{merge, MergeError, MultiDataflow, ProfileConfig, SBox};
pub use sig::{build_network, ActorKind, ActorSig, Network};
