//! Actor signatures: the sharing equivalence used by the composer.
//!
//! Two actors can share one hardware instance iff their signatures are
//! equal: same template kind, same hyper-parameters (shapes, folding), same
//! data precision, and — for actors embedding ROMs — the same weight
//! contents (fingerprinted). This matches the paper's "sharing layers of
//! different profiles that use the same data precision", refined with the
//! weight fingerprint so that merely-same-shaped layers with different
//! trained parameters are NOT collapsed.

use crate::dataflow::FoldingConfig;
use crate::qonnx::{infer_shapes, Layer, QonnxModel};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActorKind {
    LineBuffer,
    ConvMac,
    MaxPool,
    Gemm,
}

/// Sharing signature of one actor instance.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ActorSig {
    pub kind: ActorKind,
    /// Template position name (conv1_linebuf, conv1, pool1, ...).
    pub name: String,
    /// Flattened shape/folding parameters (h, w, cin, cout, pe, simd ...).
    pub params: Vec<u32>,
    pub act_bits: u32,
    pub weight_bits: u32,
    /// FNV-1a fingerprint of embedded ROM contents (0 for ROM-less actors).
    pub weight_fp: u64,
    /// Fingerprint of the (small) bias/requant ROM. For the gemm head this
    /// is allowed to differ across sharers: each profile keeps its own
    /// 10-entry bias ROM behind the shared MAC array + weight ROM.
    pub bias_fp: u64,
}

/// One profile's dataflow network (linear streaming pipeline).
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub profile: String,
    pub nodes: Vec<ActorSig>,
}

pub fn fnv1a(data: impl IntoIterator<Item = i64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Derive the actor network of a model under a folding config — the MDC
/// *front end* (paper Fig. 2: "network related path").
pub fn build_network(model: &QonnxModel, fold: &FoldingConfig) -> Network {
    let shapes = infer_shapes(model);
    let mut nodes = Vec::new();
    let mut conv_idx = 0usize;
    let mut cur_bits = model.input_bits;
    let mut stream_c = model.input_shape.c;
    for (i, layer) in model.layers.iter().enumerate() {
        let s = shapes[i];
        match layer {
            Layer::Conv(c) => {
                let (pe, simd) = if conv_idx == 0 {
                    (fold.conv1_pe, fold.conv1_simd)
                } else {
                    (fold.conv2_pe, fold.conv2_simd)
                };
                nodes.push(ActorSig {
                    kind: ActorKind::LineBuffer,
                    name: format!("{}_linebuf", c.name),
                    params: vec![s.h as u32, s.w as u32, s.c as u32],
                    act_bits: cur_bits,
                    weight_bits: 0,
                    weight_fp: 0,
                    bias_fp: 0,
                });
                let wfp = fnv1a(c.w_codes.iter().map(|&x| x as i64));
                let bfp = fnv1a(
                    c.b_codes
                        .iter()
                        .copied()
                        .chain(c.mult.iter().copied())
                        .chain(c.shift.iter().copied()),
                );
                nodes.push(ActorSig {
                    kind: ActorKind::ConvMac,
                    name: c.name.clone(),
                    params: vec![
                        s.h as u32,
                        s.w as u32,
                        c.cin as u32,
                        c.cout as u32,
                        pe as u32,
                        simd as u32,
                        cur_bits,
                    ],
                    act_bits: c.act_bits,
                    weight_bits: c.weight_bits,
                    weight_fp: wfp,
                    bias_fp: bfp,
                });
                cur_bits = c.act_bits;
                stream_c = c.cout;
                conv_idx += 1;
            }
            Layer::Pool(p) => {
                nodes.push(ActorSig {
                    kind: ActorKind::MaxPool,
                    name: p.name.clone(),
                    params: vec![s.h as u32, s.w as u32, s.c as u32],
                    act_bits: cur_bits,
                    weight_bits: 0,
                    weight_fp: 0,
                    bias_fp: 0,
                });
            }
            Layer::Flatten { .. } => {}
            Layer::Dense(d) => {
                let wfp = fnv1a(d.w_codes.iter().map(|&x| x as i64));
                let bfp = fnv1a(d.b_codes.iter().copied());
                nodes.push(ActorSig {
                    kind: ActorKind::Gemm,
                    name: d.name.clone(),
                    params: vec![
                        d.in_features as u32,
                        d.out_features as u32,
                        stream_c as u32,
                        fold.dense_pe as u32,
                        fold.dense_simd as u32,
                        cur_bits,
                    ],
                    act_bits: 32,
                    weight_bits: d.weight_bits,
                    weight_fp: wfp,
                    bias_fp: bfp,
                });
            }
        }
    }
    Network {
        profile: model.profile.clone(),
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn network_has_expected_slots() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let net = build_network(&m, &FoldingConfig::default());
        let kinds: Vec<ActorKind> = net.nodes.iter().map(|n| n.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ActorKind::LineBuffer,
                ActorKind::ConvMac,
                ActorKind::MaxPool,
                ActorKind::Gemm
            ]
        );
    }

    #[test]
    fn identical_models_have_identical_sigs() {
        let a = read_str(&test_model_json(1, 2)).unwrap();
        let b = read_str(&test_model_json(1, 2)).unwrap();
        let f = FoldingConfig::default();
        assert_eq!(build_network(&a, &f).nodes, build_network(&b, &f).nodes);
    }

    #[test]
    fn weight_change_breaks_sharing() {
        let a = read_str(&test_model_json(1, 2)).unwrap();
        let json_b = test_model_json(1, 2).replacen("-2,", "-1,", 1);
        let b = read_str(&json_b).unwrap();
        let f = FoldingConfig::default();
        let na = build_network(&a, &f);
        let nb = build_network(&b, &f);
        assert_ne!(na.nodes[1].weight_fp, nb.nodes[1].weight_fp);
        // but the ROM-less line buffer still shares
        assert_eq!(na.nodes[0], nb.nodes[0]);
    }

    #[test]
    fn fnv_is_order_sensitive() {
        assert_ne!(fnv1a([1, 2, 3]), fnv1a([3, 2, 1]));
        assert_eq!(fnv1a([]), fnv1a(std::iter::empty::<i64>()));
    }
}
