//! Resource accounting of the merged engine (paper Fig. 4 top).
//!
//! merged = sum of *distinct* actor instances + SBox mux overhead.
//! Invariants (property-tested): max(inputs) <= merged <= sum(inputs) +
//! sbox overhead, and merging a profile with itself adds nothing.

use super::merge::MultiDataflow;
use super::sig::{ActorKind, ActorSig};
use crate::hls::Calibration;

/// Resource totals of a merged multi-dataflow engine.
#[derive(Debug, Clone, PartialEq)]
pub struct MergedCost {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsp: u64,
    /// LUTs spent on SBoxes only (the adaptivity overhead).
    pub sbox_luts: u64,
    pub n_instances: usize,
    pub n_shared: usize,
}

/// Estimate one actor instance from its signature (mirrors hls::estimate but
/// driven by the signature, since the merged engine has no single
/// QonnxModel).
fn actor_cost(sig: &ActorSig, cal: &Calibration) -> (u64, u64, u64, u64) {
    match sig.kind {
        ActorKind::LineBuffer => {
            let (_h, w, c) = (sig.params[0], sig.params[1], sig.params[2]);
            let row_bits = (w * c) as u64 * sig.act_bits as u64;
            let bram18 = (2 * row_bits).div_ceil(cal.bram18_bits).max(1);
            let luts = (cal.k_actor_ctrl + 9.0 * c as f64) as u64;
            (luts, (9 * c) as u64 * sig.act_bits as u64, bram18, 0)
        }
        ActorKind::ConvMac => {
            let [_h, _w, cin, cout, pe, simd, in_bits] = sig.params[..] else {
                panic!("conv sig params");
            };
            let taps = 9 * cin as usize;
            let units = (pe * simd) as f64;
            let (lut_per_mac, dsp_per_mac) =
                if in_bits > cal.dsp_threshold_bits && sig.weight_bits > cal.dsp_threshold_bits {
                    (6.0, 1u64)
                } else {
                    (
                        cal.k_mul_w * sig.weight_bits as f64
                            + cal.k_mul_a * in_bits as f64
                            + cal.k_mul_base,
                        0,
                    )
                };
            let acc_w = (in_bits + sig.weight_bits + 10) as f64;
            let luts = units * lut_per_mac
                + pe as f64 * acc_w * cal.k_acc_bit
                + pe as f64 * cal.k_requant
                + cal.k_actor_ctrl;
            let total_w_bits = (taps * cout as usize) as u64 * sig.weight_bits as u64;
            let lanes = pe as u64;
            let bram18 = lanes * (total_w_bits.div_ceil(lanes)).div_ceil(cal.bram18_bits)
                + (8 * taps as u64 * in_bits as u64).div_ceil(cal.bram18_bits);
            (
                luts as u64,
                (luts * cal.k_ff_per_lut) as u64,
                bram18,
                (units as u64) * dsp_per_mac,
            )
        }
        ActorKind::MaxPool => {
            let (_h, w, c) = (sig.params[0], sig.params[1], sig.params[2]);
            let luts = (cal.k_actor_ctrl + c as f64 * sig.act_bits as f64 * 0.6) as u64;
            ((luts), (w / 2 * c) as u64 * sig.act_bits as u64, 0, 0)
        }
        ActorKind::Gemm => {
            let [fin, fout, _c, pe, simd, in_bits] = sig.params[..] else {
                panic!("gemm sig params");
            };
            let units = (pe * simd) as f64;
            let lut_per_mac = cal.k_mul_w * sig.weight_bits as f64
                + cal.k_mul_a * in_bits as f64
                + cal.k_mul_base;
            let acc_w = (in_bits + sig.weight_bits + 12) as f64;
            let luts =
                units * lut_per_mac + fout as f64 * acc_w * cal.k_acc_bit + cal.k_actor_ctrl;
            let total_w_bits = (fin * fout) as u64 * sig.weight_bits as u64;
            let lanes = pe as u64;
            let bram18 = lanes * (total_w_bits.div_ceil(lanes)).div_ceil(cal.bram18_bits);
            (luts as u64, (luts * cal.k_ff_per_lut) as u64, bram18, 0)
        }
    }
}

/// SBox mux cost: an n-way mux of `port_bits`-wide streams plus handshake.
fn sbox_cost(n_ways: usize, port_bits: u32) -> u64 {
    // ~1 LUT6 per 2:1 mux bit; (n-1) stages; + 24 LUTs of stream handshake.
    ((n_ways - 1) as u64) * port_bits as u64 + 24
}

/// Resource totals for a merged engine.
pub fn merged_estimate(md: &MultiDataflow, cal: &Calibration) -> MergedCost {
    let (mut luts, mut ffs, mut bram18, mut dsp) = (0u64, 0u64, 0u64, 0u64);
    for slot in &md.instances {
        for sig in slot {
            let (l, f, b, d) = actor_cost(sig, cal);
            luts += l;
            ffs += f;
            bram18 += b;
            dsp += d;
        }
    }
    let sbox_luts: u64 = md
        .sboxes
        .iter()
        .map(|s| 2 * sbox_cost(s.n_ways, s.port_bits)) // demux + mux pair
        .sum();
    MergedCost {
        luts: luts + sbox_luts,
        ffs,
        bram36: bram18 as f64 / 2.0,
        dsp,
        sbox_luts,
        n_instances: md.n_instances(),
        n_shared: md.n_shared(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::merge::merge;
    use super::super::sig::build_network;
    use super::*;
    use crate::dataflow::FoldingConfig;
    use crate::qonnx::{read_str, test_model_json};
    use crate::testkit;

    fn cost_of(nets: &[super::super::sig::Network]) -> MergedCost {
        merged_estimate(&merge(nets).unwrap(), &Calibration::default())
    }

    #[test]
    fn self_merge_adds_nothing() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let mut m2 = m.clone();
        m2.profile = "B".into();
        let f = FoldingConfig::default();
        let solo = cost_of(&[build_network(&m, &f)]);
        let dup = cost_of(&[build_network(&m, &f), build_network(&m2, &f)]);
        assert_eq!(solo.luts, dup.luts);
        assert_eq!(solo.bram36, dup.bram36);
    }

    #[test]
    fn merged_bounded_by_sum_and_max() {
        testkit::check("max <= merged <= sum + sbox", |rng| {
            let f = FoldingConfig::default();
            let json_a = test_model_json(1, 2);
            // random perturbation of one weight to force partial divergence
            let json_b = if rng.bool(0.5) {
                json_a.replacen("-2,", "0,", 1)
            } else {
                json_a.replace("\"act_bits\":8", "\"act_bits\":4")
            };
            let ma = read_str(&json_a).map_err(|e| e.to_string())?;
            let mut mb = read_str(&json_b).map_err(|e| e.to_string())?;
            mb.profile = "B".into();
            let na = build_network(&ma, &f);
            let nb = build_network(&mb, &f);
            let ca = cost_of(std::slice::from_ref(&na));
            let cb = cost_of(std::slice::from_ref(&nb));
            let m = cost_of(&[na, nb]);
            crate::prop_assert!(
                m.luts >= ca.luts.max(cb.luts),
                "merged {} < max({}, {})",
                m.luts,
                ca.luts,
                cb.luts
            );
            crate::prop_assert!(
                m.luts <= ca.luts + cb.luts + m.sbox_luts,
                "merged {} > sum {} + sbox {}",
                m.luts,
                ca.luts + cb.luts,
                m.sbox_luts
            );
            Ok(())
        });
    }
}
