//! The merging algorithm: N profile networks -> one multi-dataflow.
//!
//! Walks the input networks slot-by-slot (the streaming template gives every
//! profile the same topology skeleton; a mismatch is a hard error — the
//! paper merges profiles of the *same* CNN). At each slot, actors with equal
//! signatures collapse into one shared instance; differing actors are
//! instantiated per profile and an SBox pair (demux upstream, mux
//! downstream) is recorded. Each profile gets a configuration word:
//! which instance to use at every slot — the runtime "profile switch" is
//! just selecting a configuration (Sect. 4.4).

use std::collections::BTreeMap;
use std::fmt;

use super::sig::{ActorKind, ActorSig, Network};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergeError(pub String);

impl fmt::Display for MergeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "mdc merge: {}", self.0)
    }
}

impl std::error::Error for MergeError {}

/// A switch box steering slot `slot` among `n_ways` actor instances.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SBox {
    pub slot: usize,
    pub n_ways: usize,
    /// Token port width (bits) — mux cost input.
    pub port_bits: u32,
}

/// One profile's configuration of the merged datapath.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProfileConfig {
    pub profile: String,
    /// For each slot, the index into `MultiDataflow::instances[slot]`.
    pub selection: Vec<usize>,
}

/// The merged multi-dataflow engine.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiDataflow {
    /// Per slot: the distinct actor instances bound there (1 = fully shared).
    pub instances: Vec<Vec<ActorSig>>,
    pub sboxes: Vec<SBox>,
    pub configs: Vec<ProfileConfig>,
}

impl MultiDataflow {
    /// Total distinct actor instances.
    pub fn n_instances(&self) -> usize {
        self.instances.iter().map(Vec::len).sum()
    }

    /// Instances shared by every profile.
    pub fn n_shared(&self) -> usize {
        self.instances.iter().filter(|v| v.len() == 1).count()
    }

    /// Reconstruct the pipeline of one profile (for the semantics-preservation
    /// property: must equal the original standalone network).
    pub fn pipeline_of(&self, profile: &str) -> Option<Vec<&ActorSig>> {
        let cfg = self.configs.iter().find(|c| c.profile == profile)?;
        Some(
            cfg.selection
                .iter()
                .enumerate()
                .map(|(slot, &idx)| &self.instances[slot][idx])
                .collect(),
        )
    }

    pub fn profile_names(&self) -> Vec<&str> {
        self.configs.iter().map(|c| c.profile.as_str()).collect()
    }
}

/// Width-subsuming sharing (paper Sect. 4.4): ROM-less stream actors
/// (line buffers, pools) and the gemm head are shareable across profiles
/// whose streams differ only in *port width* — the wider datapath carries
/// the narrower codes unchanged (and the gemm emits raw accumulators, whose
/// argmax is invariant to the positive per-profile input scale). Conv MAC
/// actors requantize, so they share only on exact signature equality.
fn compatible(a: &ActorSig, b: &ActorSig) -> bool {
    if a == b {
        return true;
    }
    match a.kind {
        ActorKind::LineBuffer | ActorKind::MaxPool => {
            a.kind == b.kind && a.name == b.name && a.params == b.params
        }
        ActorKind::Gemm => {
            // params = [fin, fout, c, pe, simd, in_bits]: all but in_bits
            // must match, plus identical *weight* ROM contents. The bias ROM
            // (fout entries, scale-dependent) stays per-profile behind the
            // shared MAC array, so bias_fp is deliberately ignored.
            a.kind == b.kind
                && a.name == b.name
                && a.weight_bits == b.weight_bits
                && a.weight_fp == b.weight_fp
                && a.params.len() == b.params.len()
                && a.params[..a.params.len() - 1] == b.params[..b.params.len() - 1]
        }
        ActorKind::ConvMac => false, // only exact equality (handled above)
    }
}

/// Widen the retained instance to the max port width of the sharers.
fn widen(existing: &mut ActorSig, other: &ActorSig) {
    existing.act_bits = existing.act_bits.max(other.act_bits);
    match existing.kind {
        ActorKind::Gemm => {
            let last = existing.params.len() - 1;
            existing.params[last] = existing.params[last].max(other.params[last]);
        }
        ActorKind::LineBuffer | ActorKind::MaxPool | ActorKind::ConvMac => {}
    }
}

/// Merge N networks into a multi-dataflow.
pub fn merge(networks: &[Network]) -> Result<MultiDataflow, MergeError> {
    if networks.is_empty() {
        return Err(MergeError("no input networks".into()));
    }
    let n_slots = networks[0].nodes.len();
    for net in networks {
        if net.nodes.len() != n_slots {
            return Err(MergeError(format!(
                "profile '{}' has {} template slots, expected {} — profiles must \
                 instantiate the same streaming template",
                net.profile,
                net.nodes.len(),
                n_slots
            )));
        }
    }
    {
        let mut seen = std::collections::BTreeSet::new();
        for net in networks {
            if !seen.insert(&net.profile) {
                return Err(MergeError(format!("duplicate profile '{}'", net.profile)));
            }
        }
    }
    for slot in 0..n_slots {
        let kind = networks[0].nodes[slot].kind;
        for net in networks {
            if net.nodes[slot].kind != kind {
                return Err(MergeError(format!(
                    "slot {slot}: kind mismatch between profiles ({:?} vs {:?})",
                    kind, net.nodes[slot].kind
                )));
            }
        }
    }

    let mut instances: Vec<Vec<ActorSig>> = vec![Vec::new(); n_slots];
    let mut selections: BTreeMap<String, Vec<usize>> = networks
        .iter()
        .map(|n| (n.profile.clone(), Vec::with_capacity(n_slots)))
        .collect();

    for slot in 0..n_slots {
        for net in networks {
            let sig = &net.nodes[slot];
            let idx = match instances[slot]
                .iter()
                .position(|s| compatible(s, sig))
            {
                Some(i) => {
                    widen(&mut instances[slot][i], sig);
                    i
                }
                None => {
                    instances[slot].push(sig.clone());
                    instances[slot].len() - 1
                }
            };
            selections.get_mut(&net.profile).unwrap().push(idx);
        }
    }

    let sboxes = instances
        .iter()
        .enumerate()
        .filter(|(_, inst)| inst.len() > 1)
        .map(|(slot, inst)| SBox {
            slot,
            n_ways: inst.len(),
            // the SBox switches the actor's *input* stream width
            port_bits: inst[0].params.last().copied().unwrap_or(8).min(32),
        })
        .collect();

    let configs = networks
        .iter()
        .map(|n| ProfileConfig {
            profile: n.profile.clone(),
            selection: selections[&n.profile].clone(),
        })
        .collect();

    Ok(MultiDataflow {
        instances,
        sboxes,
        configs,
    })
}

#[cfg(test)]
mod tests {
    use super::super::sig::build_network;
    use super::*;
    use crate::dataflow::FoldingConfig;
    use crate::qonnx::{read_str, test_model_json};

    fn net(json: &str, profile: &str) -> Network {
        let mut m = read_str(json).unwrap();
        m.profile = profile.to_string();
        build_network(&m, &FoldingConfig::default())
    }

    #[test]
    fn identical_profiles_fully_share() {
        let a = net(&test_model_json(1, 2), "A");
        let b = net(&test_model_json(1, 2), "B");
        let md = merge(&[a.clone(), b]).unwrap();
        assert_eq!(md.n_instances(), a.nodes.len());
        assert!(md.sboxes.is_empty());
        assert_eq!(md.pipeline_of("A").unwrap().len(), a.nodes.len());
    }

    #[test]
    fn differing_inner_layer_gets_sbox() {
        let a = net(&test_model_json(1, 2), "A");
        // B differs only in conv weights -> conv actor duplicated, SBox there
        let json_b = test_model_json(1, 2).replacen("-2,", "-1,", 1);
        let b = net(&json_b, "B");
        let md = merge(&[a.clone(), b]).unwrap();
        assert_eq!(md.n_instances(), a.nodes.len() + 1);
        assert_eq!(md.sboxes.len(), 1);
        assert_eq!(md.sboxes[0].n_ways, 2);
        // per-profile pipelines reconstruct the originals
        let pa = md.pipeline_of("A").unwrap();
        assert_eq!(pa.into_iter().cloned().collect::<Vec<_>>(), a.nodes);
    }

    #[test]
    fn topology_mismatch_rejected() {
        let a = net(&test_model_json(1, 2), "A");
        let mut b = net(&test_model_json(1, 2), "B");
        b.nodes.pop();
        assert!(merge(&[a, b]).is_err());
    }

    #[test]
    fn duplicate_profile_rejected() {
        let a = net(&test_model_json(1, 2), "A");
        let b = net(&test_model_json(1, 2), "A");
        assert!(merge(&[a, b]).is_err());
    }

    #[test]
    fn empty_input_rejected() {
        assert!(merge(&[]).is_err());
    }

    #[test]
    fn merge_is_idempotent_for_single_network() {
        let a = net(&test_model_json(2, 3), "solo");
        let md = merge(std::slice::from_ref(&a)).unwrap();
        assert_eq!(md.n_instances(), a.nodes.len());
        assert_eq!(md.n_shared(), a.nodes.len());
    }
}
