//! Metrics substrate: counters + latency histograms for the coordinator,
//! plus the [`MetricsRegistry`] that unifies them behind named handles with
//! one JSON exposition path (see `docs/observability.md`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Value;

/// Monotonic counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (lock-free), e.g. the dispatch queue depth.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Float gauge (lock-free; f64 bits in an AtomicU64), e.g. the per-shard
/// remaining-battery fraction. `set` is last-write-wins; `add` is a CAS
/// read-modify-write accumulator safe under concurrent writers (used for
/// summed quantities like recharged joules).
#[derive(Debug)]
pub struct FloatGauge {
    bits: AtomicU64,
}

impl Default for FloatGauge {
    fn default() -> Self {
        FloatGauge::new(0.0)
    }
}

impl FloatGauge {
    pub fn new(v: f64) -> Self {
        FloatGauge {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Accumulate into the gauge (CAS loop — safe under concurrent
    /// writers), e.g. the per-shard recharged-joules total.
    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram (microseconds). Buckets: 1us .. ~17min in
/// x2 steps — cheap, fixed memory, good-enough percentiles for reports.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 30;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(N_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Exact nearest-rank quantile over an *ascending-sorted* sample set: the
/// smallest value with at least `ceil(q * n)` samples <= it. The log-bucketed
/// [`Histogram`] answers quantiles as bucket upper bounds (fine for live
/// gauges); open-loop load reports retain every latency, so their
/// p50/p99/p999 can and should be exact.
pub fn exact_quantile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((n as f64 * q).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// Unified, named metrics registry: get-or-create handles for the four
/// primitive instrument kinds, each shared as an `Arc` so the hot path keeps
/// its direct lock-free handle while [`MetricsRegistry::snapshot`] offers one
/// JSON exposition path over everything registered. Names are dotted paths
/// (`serve.requests`, `net.shed`, `serve.shard_depth.3`); lookups take a
/// short-held lock, so fetch handles once at construction time, never per
/// event.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    float_gauges: Mutex<BTreeMap<String, Arc<FloatGauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    /// Get-or-create the named counter; repeated calls return the same handle.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get-or-create the named up/down gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get-or-create the named float gauge.
    pub fn float_gauge(&self, name: &str) -> Arc<FloatGauge> {
        Arc::clone(
            self.float_gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// One JSON snapshot over every registered instrument. `BTreeMap` keeps
    /// key order deterministic, so two snapshots of identical metric values
    /// serialize byte-identically. Histograms export summary statistics, not
    /// raw buckets.
    pub fn snapshot(&self) -> Value {
        let counters = Value::Object(
            self.counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, c)| (k.clone(), Value::Int(c.get() as i64)))
                .collect(),
        );
        let gauges = Value::Object(
            self.gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, g)| (k.clone(), Value::Int(g.get())))
                .collect(),
        );
        let float_gauges = Value::Object(
            self.float_gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, g)| (k.clone(), Value::Float(g.get())))
                .collect(),
        );
        let histograms = Value::Object(
            self.histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Value::obj(vec![
                            ("count", Value::Int(h.count() as i64)),
                            ("mean_us", Value::Float(h.mean_us())),
                            ("max_us", Value::Int(h.max_us() as i64)),
                            ("p50_us", Value::Int(h.quantile_us(0.50) as i64)),
                            ("p99_us", Value::Int(h.quantile_us(0.99) as i64)),
                        ]),
                    )
                })
                .collect(),
        );
        Value::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("float_gauges", float_gauges),
            ("histograms", histograms),
        ])
    }
}

/// Default [`EventLog`] ring capacity (matches the former hard stop, but the
/// ring keeps the *newest* events instead of freezing at the oldest 10k).
pub const EVENT_LOG_CAPACITY: usize = 10_000;

/// Event log capturing profile switches etc. — a fixed-capacity ring buffer
/// that overwrites the oldest entry once full and counts what it dropped, so
/// a long-running spine can neither grow it without bound nor silently lose
/// history.
#[derive(Debug)]
pub struct EventLog {
    events: Mutex<VecDeque<(std::time::Instant, String)>>,
    capacity: usize,
    dropped: Counter,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog::with_capacity(EVENT_LOG_CAPACITY)
    }
}

impl EventLog {
    pub fn with_capacity(capacity: usize) -> Self {
        EventLog {
            events: Mutex::new(VecDeque::new()),
            capacity: capacity.max(1),
            dropped: Counter::default(),
        }
    }

    #[allow(clippy::disallowed_methods)] // wall-clock: event timestamps are observational
    pub fn push(&self, msg: impl Into<String>) {
        let mut ev = self.events.lock().unwrap();
        if ev.len() == self.capacity {
            ev.pop_front();
            self.dropped.inc();
        }
        ev.push_back((std::time::Instant::now(), msg.into()));
    }

    /// Events overwritten (oldest-first) because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    pub fn snapshot(&self) -> Vec<String> {
        self.events
            .lock()
            .unwrap()
            .iter()
            .map(|(_, m)| m.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for us in [10u64, 20, 40, 80, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 230.0).abs() < 1.0);
        assert!(h.quantile_us(0.5) >= 20);
        assert!(h.quantile_us(1.0) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn exact_quantiles_nearest_rank() {
        assert_eq!(exact_quantile_us(&[], 0.99), 0);
        let one = [42u64];
        assert_eq!(exact_quantile_us(&one, 0.0), 42);
        assert_eq!(exact_quantile_us(&one, 1.0), 42);
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(exact_quantile_us(&xs, 0.50), 50);
        assert_eq!(exact_quantile_us(&xs, 0.99), 99);
        assert_eq!(exact_quantile_us(&xs, 0.999), 100);
        assert_eq!(exact_quantile_us(&xs, 1.0), 100);
    }

    #[test]
    fn gauge_tracks_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.add(-3);
        assert_eq!(g.get(), -2);
        g.set(0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn float_gauge_round_trips() {
        let g = FloatGauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(0.375);
        assert_eq!(g.get(), 0.375);
        let g = FloatGauge::new(1.0);
        assert_eq!(g.get(), 1.0);
    }

    #[test]
    fn float_gauge_accumulates_concurrently() {
        let g = std::sync::Arc::new(FloatGauge::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    g.add(0.25);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 0.25 is exact in binary, so no accumulation error is tolerated
        assert_eq!(g.get(), 1000.0);
    }

    #[test]
    fn event_log_ring_drops_oldest_and_counts() {
        let log = EventLog::with_capacity(4);
        for i in 0..4 {
            log.push(format!("e{i}"));
        }
        assert_eq!(log.dropped(), 0);
        assert_eq!(log.snapshot(), vec!["e0", "e1", "e2", "e3"]);
        // Two more pushes overwrite the two oldest entries.
        log.push("e4");
        log.push("e5");
        assert_eq!(log.dropped(), 2);
        assert_eq!(log.snapshot(), vec!["e2", "e3", "e4", "e5"]);
        // The ring never exceeds its capacity no matter how much is pushed.
        for i in 6..100 {
            log.push(format!("e{i}"));
        }
        assert_eq!(log.snapshot().len(), 4);
        assert_eq!(log.snapshot(), vec!["e96", "e97", "e98", "e99"]);
        assert_eq!(log.dropped(), 96);
    }

    #[test]
    fn event_log_capacity_floor_is_one() {
        let log = EventLog::with_capacity(0);
        log.push("a");
        log.push("b");
        assert_eq!(log.snapshot(), vec!["b"]);
        assert_eq!(log.dropped(), 1);
    }

    #[test]
    fn float_gauge_cas_stress_sums_exactly() {
        // Heavier than the smoke test above: more threads, more adds, and a
        // deliberately contended single gauge. 0.125 is exact in binary and
        // f64 addition of exact eighths up to 10_000 stays exact, so the CAS
        // loop must produce the arithmetic sum with zero tolerance.
        const THREADS: usize = 8;
        const ADDS: usize = 10_000;
        let g = std::sync::Arc::new(FloatGauge::default());
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..ADDS {
                    g.add(0.125);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.get(), (THREADS * ADDS) as f64 * 0.125);
    }

    #[test]
    fn registry_returns_shared_handles() {
        let reg = MetricsRegistry::default();
        let a = reg.counter("serve.requests");
        let b = reg.counter("serve.requests");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        // Distinct names are distinct instruments.
        let other = reg.counter("serve.batches");
        assert_eq!(other.get(), 0);
        // Same story for the other three kinds.
        reg.gauge("g").set(-7);
        assert_eq!(reg.gauge("g").get(), -7);
        reg.float_gauge("f").set(0.5);
        assert_eq!(reg.float_gauge("f").get(), 0.5);
        reg.histogram("h").record_us(10);
        assert_eq!(reg.histogram("h").count(), 1);
    }

    #[test]
    fn registry_snapshot_is_deterministic_json() {
        let reg = MetricsRegistry::default();
        reg.counter("b.second").add(2);
        reg.counter("a.first").inc();
        reg.gauge("depth").set(3);
        reg.float_gauge("battery").set(0.75);
        reg.histogram("latency").record_us(100);
        let snap = reg.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("a.first").and_then(Value::as_i64), Some(1));
        assert_eq!(counters.get("b.second").and_then(Value::as_i64), Some(2));
        let gauges = snap.get("gauges").unwrap();
        assert_eq!(gauges.get("depth").and_then(Value::as_i64), Some(3));
        let floats = snap.get("float_gauges").unwrap();
        assert_eq!(floats.get("battery").and_then(Value::as_f64), Some(0.75));
        let h = snap.get("histograms").and_then(|h| h.get("latency")).unwrap();
        assert_eq!(h.get("count").and_then(Value::as_i64), Some(1));
        // Byte-identical exposition for identical metric state.
        assert_eq!(snap.to_string(), reg.snapshot().to_string());
    }

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }
}
