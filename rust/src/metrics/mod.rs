//! Metrics substrate: counters + latency histograms for the coordinator.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Up/down gauge (lock-free), e.g. the dispatch queue depth.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Float gauge (lock-free; f64 bits in an AtomicU64), e.g. the per-shard
/// remaining-battery fraction. `set` is last-write-wins; `add` is a CAS
/// read-modify-write accumulator safe under concurrent writers (used for
/// summed quantities like recharged joules).
#[derive(Debug)]
pub struct FloatGauge {
    bits: AtomicU64,
}

impl Default for FloatGauge {
    fn default() -> Self {
        FloatGauge::new(0.0)
    }
}

impl FloatGauge {
    pub fn new(v: f64) -> Self {
        FloatGauge {
            bits: AtomicU64::new(v.to_bits()),
        }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Accumulate into the gauge (CAS loop — safe under concurrent
    /// writers), e.g. the per-shard recharged-joules total.
    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram (microseconds). Buckets: 1us .. ~17min in
/// x2 steps — cheap, fixed memory, good-enough percentiles for reports.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 30;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(N_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Exact nearest-rank quantile over an *ascending-sorted* sample set: the
/// smallest value with at least `ceil(q * n)` samples <= it. The log-bucketed
/// [`Histogram`] answers quantiles as bucket upper bounds (fine for live
/// gauges); open-loop load reports retain every latency, so their
/// p50/p99/p999 can and should be exact.
pub fn exact_quantile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((n as f64 * q).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// Event log capturing profile switches etc. (bounded).
#[derive(Debug, Default)]
pub struct EventLog {
    events: Mutex<Vec<(std::time::Instant, String)>>,
}

impl EventLog {
    #[allow(clippy::disallowed_methods)] // wall-clock: event timestamps are observational
    pub fn push(&self, msg: impl Into<String>) {
        let mut ev = self.events.lock().unwrap();
        if ev.len() < 10_000 {
            ev.push((std::time::Instant::now(), msg.into()));
        }
    }

    pub fn snapshot(&self) -> Vec<String> {
        self.events
            .lock()
            .unwrap()
            .iter()
            .map(|(_, m)| m.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for us in [10u64, 20, 40, 80, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 230.0).abs() < 1.0);
        assert!(h.quantile_us(0.5) >= 20);
        assert!(h.quantile_us(1.0) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn exact_quantiles_nearest_rank() {
        assert_eq!(exact_quantile_us(&[], 0.99), 0);
        let one = [42u64];
        assert_eq!(exact_quantile_us(&one, 0.0), 42);
        assert_eq!(exact_quantile_us(&one, 1.0), 42);
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(exact_quantile_us(&xs, 0.50), 50);
        assert_eq!(exact_quantile_us(&xs, 0.99), 99);
        assert_eq!(exact_quantile_us(&xs, 0.999), 100);
        assert_eq!(exact_quantile_us(&xs, 1.0), 100);
    }

    #[test]
    fn gauge_tracks_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.add(-3);
        assert_eq!(g.get(), -2);
        g.set(0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn float_gauge_round_trips() {
        let g = FloatGauge::default();
        assert_eq!(g.get(), 0.0);
        g.set(0.375);
        assert_eq!(g.get(), 0.375);
        let g = FloatGauge::new(1.0);
        assert_eq!(g.get(), 1.0);
    }

    #[test]
    fn float_gauge_accumulates_concurrently() {
        let g = std::sync::Arc::new(FloatGauge::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    g.add(0.25);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 0.25 is exact in binary, so no accumulation error is tolerated
        assert_eq!(g.get(), 1000.0);
    }

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }
}
