//! Minimal vendored stand-in for the `anyhow` crate (the build environment
//! is offline, so crates.io is unavailable).
//!
//! Implements the subset this workspace uses, with anyhow's semantics:
//!
//! * [`Error`] — an error value carrying a chain of context messages.
//!   `Display` prints the outermost message; `{:#}` prints the whole chain
//!   separated by `": "`; `Debug` prints a `Caused by:` list.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!   `Result<T, E: std::error::Error>`, on `Result<T, Error>`, and on
//!   `Option<T>`.
//! * [`anyhow!`] / [`bail!`] — message-formatting constructors.
//! * A blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts concrete errors into [`Error`] (like real anyhow, `Error`
//!   itself deliberately does not implement `std::error::Error`).

use std::fmt;

/// Error value: an outermost message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain().pop().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Flatten the std source chain into our context chain so `{:#}`
        // and Debug keep showing the full cause list.
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err = Error::msg(msgs.pop().expect("error has a message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    // Sealed helper so `Context` works both on `Result<T, E: std::error::Error>`
    // and on `Result<T, anyhow::Error>` without overlapping impls (the same
    // architecture real anyhow uses).
    use super::Error;
    use std::fmt::Display;

    pub trait StdError {
        fn ext_context<C: Display>(self, ctx: C) -> Error;
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, ctx: C) -> Error {
            self.context(ctx)
        }
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, ctx: C) -> Error {
            Error::from(self).context(ctx)
        }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: ext::StdError,
{
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_prints_outermost_alternate_prints_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("middle").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
        assert_eq!(e.chain(), vec!["outer", "middle", "inner"]);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "file missing");
    }

    #[test]
    fn option_context_and_with_context() {
        let n: Option<u8> = None;
        assert_eq!(n.context("missing n").unwrap_err().to_string(), "missing n");
        let n: Option<u8> = Some(3);
        assert_eq!(n.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_nests() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u8> {
            if fail {
                bail!("failed with code {}", 2);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 2");
    }
}
