//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the PJRT CPU plugin and compiles AOT HLO artifacts;
//! neither is available in this environment. This stub keeps the API
//! surface `onnx2hw::runtime` compiles against, and gates the whole PJRT
//! path off at its single entry point: [`PjRtClient::cpu`] returns an
//! error, so `PjrtEngine::new()` fails with an actionable message and
//! callers fall back to (or skip in favor of) the bit-exact integer Sim
//! backend. No other method can ever be reached on a live value.

use std::fmt;
use std::path::Path;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA runtime unavailable (vendored xla stub; \
         use the Sim backend or build against the real xla bindings)"
    )))
}

/// PJRT client handle. The stub can never construct one.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable (unreachable in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer (unreachable in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal. Construction is allowed (it is pure host data); every
/// operation that would need the runtime errors.
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto (unreachable: parsing needs the runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not build a client");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_is_constructible_but_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
    }
}
