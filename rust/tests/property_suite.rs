//! Cross-module property suite (DESIGN.md §7) — invariants that span
//! substrate boundaries, driven by the in-house testkit.

use onnx2hw::analysis::{self, Interval};
use onnx2hw::approx::{derive_model, knobs_for};
use onnx2hw::dataflow::{exec, simulate_image, BatchExecutor, FoldingConfig};
use onnx2hw::hls::{estimate_engine, Calibration};
use onnx2hw::json::{self, Value};
use onnx2hw::mdc;
use onnx2hw::metrics::{exact_quantile_us, Histogram};
use onnx2hw::qonnx::{self, read_str, RandModelCfg};
use onnx2hw::testkit::{self, Rng};

/// Random JSON value generator (bounded depth/size).
fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    let pick = if depth == 0 { rng.u64(0, 4) } else { rng.u64(0, 6) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(rng.bool(0.5)),
        2 => Value::Int(rng.i64(i64::MIN / 2, i64::MAX / 2)),
        3 => {
            // finite doubles incl. subnormal-ish magnitudes
            let m = rng.f64(-1.0, 1.0);
            let e = rng.i64(-200, 200) as i32;
            Value::Float(m * 10f64.powi(e))
        }
        4 => Value::Str(rng.string(24)),
        5 => Value::Array(
            (0..rng.usize(0, 6))
                .map(|_| gen_value(rng, depth - 1))
                .collect(),
        ),
        _ => Value::Object(
            (0..rng.usize(0, 6))
                .map(|_| (rng.string(8), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn json_round_trip_on_random_values() {
    testkit::check("parse(serialize(v)) == v", |rng| {
        let v = gen_value(rng, 4);
        let text = json::to_string(&v);
        let back = json::parse(&text).map_err(|e| format!("{e}: {text}"))?;
        onnx2hw::prop_assert!(back == v, "round trip changed value: {text}");
        // pretty printer agrees too
        let back2 = json::parse(&json::to_string_pretty(&v)).map_err(|e| e.to_string())?;
        onnx2hw::prop_assert!(back2 == v, "pretty round trip changed value");
        Ok(())
    });
}

#[test]
fn executor_is_deterministic_and_input_sensitive() {
    testkit::check("exec deterministic", |rng| {
        let cfg = RandModelCfg::gen(rng);
        let m = read_str(&qonnx::random_model_json(&cfg, rng)).map_err(|e| e.to_string())?;
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|_| rng.u64(0, 255) as u8).collect();
        let a = exec::execute(&m, &img);
        let b = exec::execute(&m, &img);
        onnx2hw::prop_assert!(a == b, "nondeterministic executor");
        Ok(())
    });
}

#[test]
fn batched_packed_kernels_match_scalar_oracle() {
    // The serving hot path (CompiledModel + BatchExecutor) must produce the
    // exact integers of the scalar reference path for every model, batch
    // size, and image: packing, tiling, arena reuse, and batch-major order
    // must never change a logit. Batch sizes cover the batcher envelope
    // (solo request / partial batch / full batch-8), and one executor is
    // reused across them so stale arena contents would be caught.
    testkit::check("packed batch == scalar oracle", |rng| {
        let cfg = RandModelCfg::gen(rng);
        let m = read_str(&qonnx::random_model_json(&cfg, rng)).map_err(|e| e.to_string())?;
        let elems = m.input_shape.elems();
        let k = m.dense().map(|d| d.out_features).unwrap_or(0);
        let mut ex = BatchExecutor::from_model(&m);
        for &batch in &[1usize, 3, 8] {
            let images: Vec<Vec<u8>> = (0..batch)
                .map(|_| (0..elems).map(|_| rng.u64(0, 255) as u8).collect())
                .collect();
            let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
            let got = ex.run_batch(&refs);
            for (i, img) in images.iter().enumerate() {
                let want = exec::execute(&m, img);
                onnx2hw::prop_assert!(
                    got[i * k..(i + 1) * k] == want[..],
                    "cfg {cfg:?}: batch {batch} image {i} diverges from oracle"
                );
            }
        }
        Ok(())
    });
}

/// Widest `[lo, hi]` covering every per-channel interval (None when empty).
fn envelope(ivs: &[Interval]) -> Option<(i64, i64)> {
    ivs.iter().fold(None, |e, iv| match e {
        None => Some((iv.lo, iv.hi)),
        Some((lo, hi)) => Some((lo.min(iv.lo), hi.max(iv.hi))),
    })
}

#[test]
fn analysis_intervals_contain_every_observed_value() {
    // Soundness of the static verifier: on random models x random knob
    // vectors, the proven per-layer intervals must contain every
    // accumulator/activation value the scalar oracle actually produces,
    // and a layer proven i32-narrow must never observe an accumulator
    // outside i32. Derived models may legitimately carry error
    // diagnostics (e.g. a bit-drop zeroing a weight tensor) — soundness
    // has to hold on them regardless.
    testkit::check("analysis soundness vs scalar oracle", |rng| {
        let cfg = RandModelCfg::gen(rng);
        let base = read_str(&qonnx::random_model_json(&cfg, rng)).map_err(|e| e.to_string())?;
        let knobs = knobs_for(&base);
        let config: Vec<u32> = knobs.iter().map(|k| rng.u64(0, k.max as u64) as u32).collect();
        let m = derive_model(&base, &config, "prop");
        let an = analysis::analyze(&m);
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|_| rng.u64(0, 255) as u8).collect();
        let (logits, traces) = exec::execute_traced(&m, &img);
        onnx2hw::prop_assert!(traces.len() == an.facts.len(), "trace/facts misaligned");
        for (i, (trace, facts)) in traces.iter().zip(&an.facts).enumerate() {
            if let Some((lo, hi)) = trace.acc {
                let (alo, ahi) = envelope(&facts.acc).ok_or("acc facts missing")?;
                onnx2hw::prop_assert!(
                    alo <= lo && hi <= ahi,
                    "cfg {cfg:?} config {config:?} layer {i} '{}': \
                     observed acc [{lo},{hi}] outside proven [{alo},{ahi}]",
                    facts.name
                );
                if facts.narrow == Some(true) {
                    onnx2hw::prop_assert!(
                        lo >= i32::MIN as i64 && hi <= i32::MAX as i64,
                        "layer {i} '{}' proven narrow but observed acc [{lo},{hi}]",
                        facts.name
                    );
                }
            }
            if let Some((lo, hi)) = trace.act {
                let (alo, ahi) = envelope(&facts.act).ok_or("act facts missing")?;
                onnx2hw::prop_assert!(
                    alo <= lo && hi <= ahi,
                    "cfg {cfg:?} config {config:?} layer {i} '{}': \
                     observed act [{lo},{hi}] outside proven [{alo},{ahi}]",
                    facts.name
                );
            }
        }
        if let Some((llo, lhi)) = envelope(&an.logits) {
            for &v in &logits {
                onnx2hw::prop_assert!(
                    llo <= v && v <= lhi,
                    "logit {v} outside proven [{llo},{lhi}]"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn error_bounds_contain_observed_deviation() {
    // Soundness of the affine error-bound analyzer: on random models x
    // random knob vectors, every element-wise deviation the scalar oracle
    // observes between the base and the derived variant (in aligned
    // base-code units) lies inside the proven per-channel interval — and a
    // certified-exact variant never changes a single logit or the argmax.
    testkit::check("error bounds vs observed deviation", |rng| {
        let cfg = RandModelCfg::gen(rng);
        let base = read_str(&qonnx::random_model_json(&cfg, rng)).map_err(|e| e.to_string())?;
        let knobs = knobs_for(&base);
        let config: Vec<u32> = knobs.iter().map(|k| rng.u64(0, k.max as u64) as u32).collect();
        let variant = derive_model(&base, &config, "prop-err");
        let report = analysis::analyze_error(&base, &config);
        let img: Vec<u8> = (0..base.input_shape.elems()).map(|_| rng.u64(0, 255) as u8).collect();
        let (blogits, bcaps) = exec::execute_captured(&base, &img);
        let (vlogits, vcaps) = exec::execute_captured(&variant, &img);
        onnx2hw::prop_assert!(
            report.layers.len() == bcaps.len() && bcaps.len() == vcaps.len(),
            "layers/captures misaligned"
        );
        // Mirror the report's saturation policy: proven endpoints live in
        // saturated i64, so the observed deviation is clamped the same way.
        let sat = |v: i128| v.clamp(i64::MIN as i128, i64::MAX as i128);
        let contains = |ivs: &[Interval], scale_log2: u32, b: &[i64], v: &[i64], what: &str, i: usize| {
            if b.is_empty() && v.is_empty() {
                return Ok(());
            }
            if ivs.is_empty() || b.len() != v.len() {
                return Err(format!("layer {i} {what}: capture/deviation shape mismatch"));
            }
            let s = 1i128 << scale_log2;
            for (e, (&bv, &vv)) in b.iter().zip(v).enumerate() {
                let iv = &ivs[e % ivs.len()];
                let d = sat(vv as i128 * s - bv as i128);
                if !(iv.lo as i128 <= d && d <= iv.hi as i128) {
                    return Err(format!(
                        "layer {i} {what} elem {e}: observed deviation {d} outside \
                         proven [{}, {}]",
                        iv.lo, iv.hi
                    ));
                }
            }
            Ok(())
        };
        for (i, dev) in report.layers.iter().enumerate() {
            contains(&dev.acc_dev, dev.acc_scale_log2, &bcaps[i].acc, &vcaps[i].acc, "acc", i)
                .map_err(|e| format!("cfg {cfg:?} config {config:?}: {e}"))?;
            contains(&dev.act_dev, dev.act_scale_log2, &bcaps[i].act, &vcaps[i].act, "act", i)
                .map_err(|e| format!("cfg {cfg:?} config {config:?}: {e}"))?;
        }
        if report.certified_exact && !blogits.is_empty() {
            onnx2hw::prop_assert!(
                exec::argmax(&blogits) == exec::argmax(&vlogits),
                "cfg {cfg:?} config {config:?}: certified-exact variant flipped the argmax"
            );
        }
        Ok(())
    });
}

#[test]
fn merged_engine_preserves_profile_semantics() {
    // Simulating a profile's reconstructed pipeline == simulating the
    // standalone model (here: the reconstructed pipeline must select the
    // exact actor set whose sigs match the standalone network, modulo
    // width-widening on shareable stream actors).
    testkit::check("merge preserves semantics", |rng| {
        let fold = FoldingConfig::default();
        let base_json = qonnx::test_model_json(2, 3);
        let variant_json = if rng.bool(0.5) {
            base_json.replacen("-2,", "2,", 1) // different conv weights
        } else {
            base_json.replace("\"act_bits\":8", "\"act_bits\":4")
        };
        let mut a = read_str(&base_json).map_err(|e| e.to_string())?;
        a.profile = "A".into();
        let mut b = read_str(&variant_json).map_err(|e| e.to_string())?;
        b.profile = "B".into();
        let na = mdc::build_network(&a, &fold);
        let nb = mdc::build_network(&b, &fold);
        let md = mdc::merge(&[na.clone(), nb.clone()]).map_err(|e| e.to_string())?;
        for (net, name) in [(&na, "A"), (&nb, "B")] {
            let pipe = md.pipeline_of(name).ok_or("missing config")?;
            onnx2hw::prop_assert!(pipe.len() == net.nodes.len());
            for (got, want) in pipe.iter().zip(&net.nodes) {
                onnx2hw::prop_assert!(
                    got.kind == want.kind
                        && got.name == want.name
                        && got.weight_fp == want.weight_fp
                        && got.act_bits >= want.act_bits,
                    "profile {name}: slot {} diverged",
                    want.name
                );
            }
        }
        Ok(())
    });
}

#[test]
fn merge_scales_to_many_profiles() {
    // N identical + one divergent profile: instances = slots + 1, and every
    // profile reconstructs.
    let fold = FoldingConfig::default();
    let base = qonnx::test_model_json(1, 2);
    let variant = base.replacen("-2,", "1,", 1);
    let mut nets = Vec::new();
    for i in 0..5 {
        let mut m = read_str(&base).unwrap();
        m.profile = format!("p{i}");
        nets.push(mdc::build_network(&m, &fold));
    }
    let mut v = read_str(&variant).unwrap();
    v.profile = "variant".into();
    nets.push(mdc::build_network(&v, &fold));
    let md = mdc::merge(&nets).unwrap();
    assert_eq!(md.n_instances(), nets[0].nodes.len() + 1);
    assert_eq!(md.configs.len(), 6);
    for net in &nets {
        assert!(md.pipeline_of(&net.profile).is_some());
    }
}

#[test]
fn resources_monotone_in_weight_bits_property() {
    testkit::check("luts monotone in w-bits", |rng| {
        // Force 4-bit weights at generation time (codes within ±7), so the
        // same codes remain valid when the declaration widens to 8 bits.
        let mut cfg = RandModelCfg::gen(rng);
        cfg.blocks = cfg.blocks.iter().map(|&(f, a, _)| (f, a, 4)).collect();
        let json4 = qonnx::random_model_json(&cfg, rng);
        let json8 = json4.replace("\"weight_bits\":4", "\"weight_bits\":8");
        let m4 = read_str(&json4).map_err(|e| e.to_string())?;
        let m8 = read_str(&json8).map_err(|e| e.to_string())?;
        let cal = Calibration::default();
        let f = FoldingConfig::default();
        let l4 = estimate_engine(&m4, &f, &cal).luts;
        let l8 = estimate_engine(&m8, &f, &cal).luts;
        onnx2hw::prop_assert!(l8 >= l4, "w8 {l8} < w4 {l4}");
        Ok(())
    });
}

#[test]
fn sim_cycles_depend_only_on_structure() {
    testkit::check("cycles invariant to data + weights", |rng| {
        let cfg = RandModelCfg::gen(rng);
        let json_a = qonnx::random_model_json(&cfg, rng);
        let m = read_str(&json_a).map_err(|e| e.to_string())?;
        let fold = FoldingConfig::default();
        let img_a: Vec<u8> = (0..m.input_shape.elems()).map(|_| rng.u64(0, 255) as u8).collect();
        let img_b: Vec<u8> = (0..m.input_shape.elems()).map(|_| rng.u64(0, 255) as u8).collect();
        let ca = simulate_image(&m, &fold, &img_a).cycles;
        let cb = simulate_image(&m, &fold, &img_b).cycles;
        onnx2hw::prop_assert!(ca == cb, "cycles vary with data: {ca} vs {cb}");
        Ok(())
    });
}

#[test]
fn requant_saturates_never_wraps() {
    testkit::check("requant output in range", |rng| {
        let acc = rng.i64(-(1 << 40), 1 << 40);
        let mult = rng.i64(0, 1 << 20);
        let shift = rng.i64(0, 40);
        let bits = *rng.pick(&[1u32, 4, 8, 16]);
        let q = exec::requant(acc, mult, shift, bits);
        onnx2hw::prop_assert!(
            (0..(1i64 << bits)).contains(&q),
            "requant({acc},{mult},{shift},{bits}) = {q} out of range"
        );
        Ok(())
    });
}

#[test]
fn histogram_quantile_brackets_exact_within_one_bucket() {
    // The live registry's log2-bucketed histogram answers quantiles as the
    // upper bound of the bucket holding the exact nearest-rank value
    // (`metrics::exact_quantile_us` over the retained samples): for exact
    // e >= 1 the estimate must be 2^(floor(log2 e) + 1), i.e. e < est <= 2e
    // — never off by more than one bucket, never below the truth.
    testkit::check("histogram quantile brackets exact", |rng| {
        let h = Histogram::default();
        let n = rng.usize(1, 400);
        let mut samples: Vec<u64> = (0..n)
            .map(|_| {
                // Span the full bucket range while staying clear of the
                // top-bucket clamp (values >= 2^29 all share one bucket).
                let exp = rng.u64(0, 28);
                rng.u64(1 << exp, (1 << (exp + 1)) - 1)
            })
            .collect();
        for &s in &samples {
            h.record_us(s);
        }
        samples.sort_unstable();
        // q = 0 is excluded: nearest-rank pins it to the minimum sample,
        // while the bucket walk's ceil(n*q) target degenerates to zero.
        for &q in &[0.001, 0.01, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let exact = exact_quantile_us(&samples, q);
            let est = h.quantile_us(q);
            let bucket_hi = 1u64 << (64 - exact.leading_zeros());
            onnx2hw::prop_assert!(
                est == bucket_hi && exact < est && est <= 2 * exact,
                "q={q}: estimate {est} does not bracket exact {exact} (bucket hi {bucket_hi})"
            );
        }
        Ok(())
    });
}
