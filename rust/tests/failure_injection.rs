//! Failure injection: corrupted artifacts, malformed QONNX, runtime-facing
//! error paths. Every failure must be a clean `Err` with an actionable
//! message — never a panic or silent wrong answer.

use std::fs;

use onnx2hw::qonnx::{self, read_str};
use onnx2hw::runtime::ArtifactStore;

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("onnx2hw_fi_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn truncated_qonnx_json_is_a_clean_error() {
    let dir = scratch("trunc");
    let full = qonnx::test_model_json(1, 2);
    for frac in [0.1, 0.5, 0.9, 0.99] {
        let cut = &full[..(full.len() as f64 * frac) as usize];
        fs::write(dir.join("model_T.qonnx.json"), cut).unwrap();
        let store = ArtifactStore::at(&dir);
        let err = store.qonnx("T").unwrap_err().to_string();
        assert!(err.contains("model_T.qonnx.json"), "error should name the file: {err}");
    }
}

#[test]
fn binary_garbage_qonnx_is_a_clean_error() {
    let dir = scratch("garbage");
    fs::write(dir.join("model_G.qonnx.json"), [0xFFu8, 0x00, 0x7F, 0xC3]).unwrap();
    let store = ArtifactStore::at(&dir);
    assert!(store.qonnx("G").is_err());
}

#[test]
fn testset_size_mismatch_detected() {
    let dir = scratch("testset");
    fs::write(
        dir.join("testset.json"),
        r#"{"n": 4, "height": 28, "width": 28, "channels": 1, "labels": [1,2,3,4]}"#,
    )
    .unwrap();
    // wrong byte count: 3 images instead of 4
    fs::write(dir.join("testset.bin"), vec![0u8; 3 * 28 * 28]).unwrap();
    let store = ArtifactStore::at(&dir);
    let err = store.testset().unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");
}

#[test]
fn missing_artifacts_dir_reports_actionable_message() {
    let store = ArtifactStore::at("/nonexistent/path/artifacts");
    assert!(store.profiles().is_err());
    assert!(store.testset().is_err());
}

#[test]
fn eval_record_with_missing_field_rejected() {
    let dir = scratch("eval");
    fs::write(dir.join("eval_X.json"), r#"{"profile": "X"}"#).unwrap();
    let store = ArtifactStore::at(&dir);
    let err = store.eval("X").unwrap_err().to_string();
    assert!(err.contains("int_accuracy"), "{err}");
}

#[test]
fn qonnx_semantic_corruptions_rejected() {
    let base = qonnx::test_model_json(2, 3);
    // each corruption must fail schema validation, not crash later
    let cases = [
        // negative shift
        base.replace("\"shift\":[15,15,15]", "\"shift\":[15,-1,15]"),
        // giant multiplier
        base.replace("\"mult\":[16384,16384,16384]", "\"mult\":[16384,9999999999,16384]"),
        // zero-bit weights
        base.replace("\"weight_bits\":4", "\"weight_bits\":0"),
        // 64-bit activations
        base.replace("\"act_bits\":8", "\"act_bits\":64"),
        // dangling output name
        base.replace("\"output\": \"logits\"", "\"output\": \"nope\""),
        // odd spatial dims for the pool (5x5 input)
        base.replace("\"shape\": [1,4,4,2]", "\"shape\": [1,5,5,2]"),
    ];
    for (i, bad) in cases.iter().enumerate() {
        assert_ne!(bad, &base, "case {i} replacement did not apply");
        assert!(read_str(bad).is_err(), "case {i} accepted corrupt model");
    }
}

#[test]
fn executor_rejects_wrong_input_size() {
    let m = read_str(&qonnx::test_model_json(1, 2)).unwrap();
    let short = vec![0u8; m.input_shape.elems() - 1];
    let result = std::panic::catch_unwind(|| onnx2hw::dataflow::execute(&m, &short));
    assert!(result.is_err(), "undersized input must be rejected");
}

#[test]
fn server_survives_backend_batch_failure() {
    // A backend that errors on every classify: the server must keep running
    // (requests dropped with an event logged), not crash the worker.
    use onnx2hw::coordinator::*;
    use std::collections::BTreeMap;

    let specs = vec![ProfileSpec {
        name: "T".into(),
        accuracy: 0.9,
        power_mw: 100.0,
        latency_us: 100.0,
    }];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(1.0);
    // Sim backend with a model whose input size will not match the images
    // we send -> classify panics are avoided by sending wrong-size images
    // only through the error path: use a model with 4x4 input but send
    // 2-byte images; Executor asserts -> we must NOT reach it. Instead use
    // a missing-profile failure: backend holds "T" but the image size check
    // errors at the PJRT layer... For the sim backend the failure mode is a
    // poisoned model lookup; emulate by registering under a different name
    // and letting ensure_profile pass via a matching name but classify fail.
    // Simplest honest injection: a backend whose model map is empty for the
    // profile at classify time cannot be built through the public API, so
    // we assert the *startup* failure path instead and that the constructor
    // cleans up.
    let empty: BTreeMap<String, onnx2hw::qonnx::QonnxModel> = BTreeMap::new();
    let result = AdaptiveServer::start(
        ServerConfig::default(),
        move || Ok(Backend::sim_from_models(empty.clone())),
        manager,
        energy,
    );
    assert!(result.is_err(), "startup must fail when profile is missing");
}

#[test]
fn random_fault_plans_resolve_every_ticket() {
    // Property: under ANY seeded FaultPlan — panics and brown-outs landing
    // on arbitrary shards at arbitrary batch ticks — every submitted ticket
    // resolves. Survivors are bit-exact against the scalar oracle;
    // casualties are typed `Err`s, never hangs or silently lost replies.
    // This is the in-process half of the chaos contract (the
    // `chaos_recovery` bench drives the same invariant over TCP); see
    // docs/robustness.md for the fault model.
    use onnx2hw::coordinator::*;
    use onnx2hw::dataflow::exec;
    use onnx2hw::fault::{FaultPlan, FaultSpec};
    use onnx2hw::testkit;
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Duration;

    let model = read_str(&qonnx::test_model_json(1, 2)).unwrap();
    let elems = model.input_shape.elems();
    let images: Vec<Vec<u8>> = (0..8)
        .map(|i| (0..elems).map(|j| ((i * 31 + j * 17) % 256) as u8).collect())
        .collect();
    let oracle: Vec<Vec<f32>> = images
        .iter()
        .map(|img| exec::execute(&model, img).iter().map(|&v| v as f32).collect())
        .collect();

    testkit::check("every ticket resolves under a random fault plan", |rng| {
        let workers = rng.usize(1, 3);
        let plan = FaultPlan::seeded(
            rng.u64(0, 1 << 48),
            &FaultSpec {
                shards: workers,
                horizon_batches: rng.u64(1, 12),
                // Wire faults need a TCP front end; the in-process spine
                // only exercises the server clock.
                horizon_requests: 1,
                panics: rng.usize(0, 2),
                brownouts: rng.usize(0, 2),
                resets: 0,
                corruptions: 0,
            },
        );
        let n_faults = plan.server.len();

        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), model.clone());
        models.insert("lo".to_string(), model.clone());
        let backend = move || Ok(Backend::sim_from_models(models.clone()));
        let specs = vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 130.0,
                latency_us: 329.0,
            },
        ];
        let mgr = ProfileManager::new(ManagerConfig::default(), specs);
        let cfg = ServerConfig {
            workers,
            restart_backoff_batches: 1,
            faults: Some(Arc::new(plan.injector())),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(10.0))
            .map_err(|e| format!("server failed to start: {e}"))?;

        let n = rng.usize(8, 24);
        let client = srv.client();
        let tickets = client.submit_many((0..n).map(|i| images[i % images.len()].clone()));
        let (mut oks, mut errs) = (0usize, 0usize);
        for (i, t) in tickets.into_iter().enumerate() {
            match t.await_reply_timeout(Duration::from_secs(10)) {
                Ok(r) => {
                    onnx2hw::prop_assert!(
                        r.logits == oracle[i % images.len()],
                        "request {i} resolved Ok but not bit-exact (profile {})",
                        r.profile
                    );
                    oks += 1;
                }
                Err(e) => {
                    // A ticket may die with its shard (typed casualty) but
                    // must never time out: that would be a hang/lost reply.
                    let msg = format!("{e:#}");
                    onnx2hw::prop_assert!(
                        !msg.contains("timed out"),
                        "request {i} hung past the 10 s deadline: {msg}"
                    );
                    errs += 1;
                }
            }
        }
        onnx2hw::prop_assert!(oks + errs == n, "conservation: every ticket must resolve");
        if n_faults == 0 {
            onnx2hw::prop_assert!(errs == 0, "no faults planned but {errs} tickets failed");
        }
        // Gauge conservation: once every ticket resolved, no queue depth may
        // linger (dead shards' accounting included). Brief grace for the
        // final decrement, which races the reply send.
        for _ in 0..500 {
            if srv.stats.drained() {
                break;
            }
            #[allow(clippy::disallowed_methods)] // wall-clock: grace for a racing gauge decrement
            std::thread::sleep(Duration::from_millis(2));
        }
        onnx2hw::prop_assert!(
            srv.stats.drained(),
            "spine gauges leaked after all tickets resolved (queue {} / shards {:?})",
            srv.stats.queue_depth.get(),
            srv.stats.shard_depth.iter().map(|g| g.get()).collect::<Vec<_>>()
        );
        srv.shutdown();
        Ok(())
    });
}
