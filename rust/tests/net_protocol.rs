//! Integration tests for the TCP wire protocol front end (`onnx2hw::net`).
//!
//! Adversarial framing (garbage bytes, oversize length prefixes, partial
//! headers, mid-request disconnects) must earn *typed* error frames, never
//! panics, and must leave every gauge — the front end's `inflight` /
//! `open_connections` and the spine's `queue_depth` / `shard_depth` — back
//! at zero. The shed path is regression-tested for gauge conservation: an
//! `Overloaded` rejection happens before the dispatcher ever sees the
//! request, so it must leave no depth increment behind (the wire twin of
//! the dead-pool drop accounting in `coordinator/server.rs`).

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::exec;
use onnx2hw::net::{
    read_frame, ErrCode, FrameError, FrameKind, NetClient, NetReply, NetServer, NetServerConfig,
    ResilientClient, RetryPolicy, HEADER_LEN, MAGIC, VERSION,
};
use onnx2hw::qonnx::{read_str, test_model_json, QonnxModel};

/// Poll `cond` for up to ~5 s; cross-thread teardown (handler joins,
/// gauge decrements) is fast but not synchronous with the client side.
#[allow(clippy::disallowed_methods)] // wall-clock: polling cross-thread teardown
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..500 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

fn synthetic_model() -> QonnxModel {
    read_str(&test_model_json(1, 2)).expect("model")
}

fn image(model: &QonnxModel, k: usize) -> Vec<u8> {
    (0..model.input_shape.elems())
        .map(|i| ((i * 31 + k * 17) % 256) as u8)
        .collect()
}

fn oracle(model: &QonnxModel, img: &[u8]) -> Vec<f32> {
    exec::execute(model, img).iter().map(|&v| v as f32).collect()
}

/// One-shard spine + net front end on a loopback port. `expect_len` turns
/// on payload-size validation (as `serve --listen` does).
fn start_stack(
    admission_depth: usize,
    max_payload: usize,
    expect_len: bool,
) -> (AdaptiveServer, NetServer, QonnxModel) {
    let model = synthetic_model();
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: 329.0,
        },
    ];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: 1,
            ..Default::default()
        },
        factory,
        manager,
        EnergyMonitor::new(10.0),
    )
    .expect("spine");
    let net = NetServer::start(
        NetServerConfig {
            admission_depth,
            max_payload,
            expected_image_len: expect_len.then(|| model.input_shape.elems()),
            ..Default::default()
        },
        srv.client(),
    )
    .expect("net server");
    (srv, net, model)
}

/// Drain the stack and assert the gauge-conservation invariant held.
fn finish(srv: AdaptiveServer, net: NetServer) {
    let net_stats = net.stats.clone();
    let srv_stats = srv.stats.clone();
    net.shutdown();
    assert_eq!(net_stats.inflight.get(), 0, "net in-flight gauge leaked");
    assert_eq!(
        net_stats.open_connections.get(),
        0,
        "connection gauge leaked"
    );
    assert!(srv_stats.drained(), "spine queue/shard gauges leaked");
    srv.shutdown();
}

/// A raw valid header: magic | version | kind | id (BE) | len (BE).
fn raw_header(kind: u8, id: u64, len: u32) -> Vec<u8> {
    let mut h = Vec::with_capacity(HEADER_LEN);
    h.extend_from_slice(&MAGIC);
    h.push(VERSION);
    h.push(kind);
    h.extend_from_slice(&id.to_be_bytes());
    h.extend_from_slice(&len.to_be_bytes());
    h
}

#[test]
fn roundtrip_is_bit_exact_and_ordered() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let n = 32;
    let replies = client
        .classify_pipelined((0..n).map(|i| image(&model, i % 8)), 8)
        .expect("pipelined");
    assert_eq!(replies.len(), n);
    for (i, reply) in replies.into_iter().enumerate() {
        match reply {
            NetReply::Response(resp) => {
                assert_eq!(resp.id, i as u64, "submission order broken");
                assert_eq!(resp.logits, oracle(&model, &image(&model, i % 8)));
                assert_eq!(resp.shard, 0);
            }
            NetReply::Denied { id, code, message } => {
                panic!("request {id} denied: {code}: {message}")
            }
        }
    }
    assert_eq!(net.stats.served.get(), n as u64);
    assert_eq!(net.stats.shed.get(), 0);
    drop(client);
    finish(srv, net);
}

#[test]
fn garbage_bytes_get_a_typed_error_then_close() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let mut raw = TcpStream::connect(net.addr()).expect("connect");
    raw.write_all(b"GARBAGE-GARBAGE-GARBAGE-GARBAGE-").expect("write");
    raw.flush().expect("flush");

    let mut reader = BufReader::new(raw.try_clone().expect("clone"));
    let frame = read_frame(&mut reader, 1 << 20).expect("typed error frame, not a hangup");
    assert_eq!(frame.kind, FrameKind::Error);
    assert_eq!(frame.id, 0, "framing errors have no request id to echo");
    let (code, message) = onnx2hw::net::decode_error(&frame.payload).expect("decodable");
    assert_eq!(code, ErrCode::BadRequest);
    assert!(message.contains("magic"), "unhelpful error: {message}");
    // The desynced stream is closed after the error frame.
    assert!(matches!(
        read_frame(&mut reader, 1 << 20),
        Err(FrameError::Closed)
    ));
    assert_eq!(net.stats.frame_errors.get(), 1);
    wait_until("garbage conn teardown", || {
        net.stats.open_connections.get() == 0
    });

    // The server survives the abuse: a well-behaved client still gets served.
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let img = image(&model, 0);
    let resp = client.classify(&img).expect("served after garbage conn");
    assert_eq!(resp.logits, oracle(&model, &img));
    drop(client);
    finish(srv, net);
}

#[test]
fn oversize_length_prefix_is_rejected_before_allocation() {
    let max_payload = 64;
    let (srv, net, _model) = start_stack(256, max_payload, false);
    let mut raw = TcpStream::connect(net.addr()).expect("connect");
    raw.write_all(&raw_header(1, 7, (max_payload as u32) + 1))
        .expect("write");
    raw.flush().expect("flush");

    let mut reader = BufReader::new(raw.try_clone().expect("clone"));
    let frame = read_frame(&mut reader, 1 << 20).expect("typed error frame");
    assert_eq!(frame.kind, FrameKind::Error);
    let (code, message) = onnx2hw::net::decode_error(&frame.payload).expect("decodable");
    assert_eq!(code, ErrCode::BadRequest);
    assert!(
        message.contains("65") && message.contains("64"),
        "error should name the limit: {message}"
    );
    assert!(matches!(
        read_frame(&mut reader, 1 << 20),
        Err(FrameError::Closed)
    ));
    assert_eq!(net.stats.frame_errors.get(), 1);
    assert_eq!(net.stats.admitted.get(), 0, "nothing reached the spine");
    wait_until("oversize conn teardown", || {
        net.stats.open_connections.get() == 0
    });
    finish(srv, net);
}

#[test]
fn partial_header_then_disconnect_leaks_nothing() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    {
        let mut raw = TcpStream::connect(net.addr()).expect("connect");
        // 9 bytes of a valid header: the reader blocks mid-frame, then we
        // hang up. The truncated read must surface as a typed FrameError,
        // not a panic.
        raw.write_all(&raw_header(1, 1, 8)[..9]).expect("write");
        raw.flush().expect("flush");
        wait_until("conn accepted", || net.stats.connections.get() == 1);
    } // drop: disconnect mid-header
    wait_until("partial conn teardown", || {
        net.stats.open_connections.get() == 0
    });
    assert_eq!(net.stats.admitted.get(), 0);
    assert_eq!(net.stats.inflight.get(), 0);
    assert!(srv.stats.drained());

    // A fresh client is unaffected.
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let img = image(&model, 3);
    let resp = client.classify(&img).expect("served");
    assert_eq!(resp.logits, oracle(&model, &img));
    drop(client);
    finish(srv, net);
}

#[test]
fn wrong_image_len_is_denied_without_closing() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let id = client.submit(&[0u8; 3]).expect("submit undersized");
    match client.recv().expect("typed denial") {
        NetReply::Denied {
            id: got,
            code,
            message,
        } => {
            assert_eq!(got, id, "denial echoes the request id");
            assert_eq!(code, ErrCode::BadRequest);
            assert!(message.contains("bytes"), "unhelpful denial: {message}");
        }
        NetReply::Response(r) => panic!("undersized image served: {r:?}"),
    }
    // Same connection keeps working: size denials do not close.
    let img = image(&model, 1);
    let resp = client.classify(&img).expect("served on the same conn");
    assert_eq!(resp.logits, oracle(&model, &img));
    assert_eq!(net.stats.bad_requests.get(), 1);
    assert_eq!(net.stats.frame_errors.get(), 0);
    drop(client);
    finish(srv, net);
}

#[test]
fn shed_path_conserves_every_gauge() {
    // Admission depth 0: every request is shed before the spine sees it.
    let (srv, net, model) = start_stack(0, 1 << 20, true);
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let n = 10;
    for _ in 0..n {
        client.submit(&image(&model, 0)).expect("submit");
    }
    for i in 0..n {
        match client.recv().expect("typed shed reply") {
            NetReply::Denied { id, code, .. } => {
                assert_eq!(id, i as u64);
                assert_eq!(code, ErrCode::Overloaded);
            }
            NetReply::Response(r) => panic!("request served past a depth-0 gate: {r:?}"),
        }
    }
    assert_eq!(net.stats.shed.get(), n as u64);
    assert_eq!(net.stats.admitted.get(), 0);
    assert_eq!(net.stats.inflight.get(), 0);
    // The regression: a shed request must never have touched the spine, so
    // its request counter is untouched and its depth gauges are conserved.
    assert_eq!(srv.stats.requests.get(), 0, "shed request reached the spine");
    assert!(srv.stats.drained(), "shed path leaked queue/shard depth");
    drop(client);
    finish(srv, net);
}

#[test]
fn admission_depth_one_still_serves_sequential_load() {
    // Depth 1 with a synchronous client: each request drains before the
    // next arrives, so nothing is ever shed.
    let (srv, net, model) = start_stack(1, 1 << 20, true);
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    for k in 0..5 {
        let img = image(&model, k);
        let resp = client.classify(&img).expect("served");
        assert_eq!(resp.logits, oracle(&model, &img));
    }
    assert_eq!(net.stats.served.get(), 5);
    assert_eq!(net.stats.shed.get(), 0);
    drop(client);
    finish(srv, net);
}

#[test]
fn mid_request_disconnect_drains_inflight_accounting() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let n = 5;
    {
        let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
        for _ in 0..n {
            client.submit(&image(&model, 0)).expect("submit");
        }
        wait_until("requests admitted", || net.stats.admitted.get() == n as u64);
    } // drop: the client vanishes with every request still in flight
    wait_until("in-flight tickets resolved after disconnect", || {
        net.stats.open_connections.get() == 0
            && net.stats.served.get() + net.stats.failed.get() == n as u64
    });
    assert_eq!(net.stats.inflight.get(), 0, "disconnect leaked inflight");
    assert!(srv.stats.drained(), "disconnect leaked spine gauges");
    finish(srv, net);
}

#[test]
fn graceful_drain_flushes_inflight_replies() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let net_stats = net.stats.clone();
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let n = 5;
    for k in 0..n {
        client.submit(&image(&model, k)).expect("submit");
    }
    wait_until("requests admitted", || {
        net_stats.admitted.get() == n as u64
    });
    // Drain while all n replies are pending: shutdown must flush them.
    net.shutdown();
    for i in 0..n {
        match client.recv().expect("flushed reply") {
            NetReply::Response(resp) => {
                assert_eq!(resp.id, i as u64);
                assert_eq!(resp.logits, oracle(&model, &image(&model, i)));
            }
            NetReply::Denied { id, code, message } => {
                panic!("in-flight request {id} dropped by drain: {code}: {message}")
            }
        }
    }
    assert!(matches!(client.recv(), Err(FrameError::Closed)));
    assert_eq!(net_stats.served.get(), n as u64);
    assert_eq!(net_stats.inflight.get(), 0);
    assert_eq!(net_stats.open_connections.get(), 0);
    assert!(srv.stats.drained());
    srv.shutdown();
}

#[test]
fn raw_response_frame_from_client_is_refused() {
    // Clients may only send Request frames; a Response kind is a protocol
    // violation answered with a typed error, then close.
    let (srv, net, _model) = start_stack(256, 1 << 20, false);
    let mut raw = TcpStream::connect(net.addr()).expect("connect");
    raw.write_all(&raw_header(2, 9, 0)).expect("write");
    raw.flush().expect("flush");
    let mut reader = BufReader::new(raw.try_clone().expect("clone"));
    let frame = read_frame(&mut reader, 1 << 20).expect("typed error frame");
    assert_eq!(frame.kind, FrameKind::Error);
    let (code, _msg) = onnx2hw::net::decode_error(&frame.payload).expect("decodable");
    assert_eq!(code, ErrCode::BadRequest);
    assert!(matches!(
        read_frame(&mut reader, 1 << 20),
        Err(FrameError::Closed)
    ));
    wait_until("refused conn teardown", || {
        net.stats.open_connections.get() == 0
    });
    finish(srv, net);
}

#[test]
fn resilient_client_reconnects_after_a_connection_reset() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let mut client = ResilientClient::new(
        &net.addr().to_string(),
        RetryPolicy {
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .with_deadline(Duration::from_secs(5));
    let img = image(&model, 0);
    let resp = client.classify(&img).expect("served before the reset");
    assert_eq!(resp.logits, oracle(&model, &img));

    // Chaos: hard-kill every open connection, then classify again — the
    // client must redial transparently and the reply stays bit-exact.
    assert!(net.reset_connections() >= 1, "nothing to reset");
    let img2 = image(&model, 1);
    let resp2 = client.classify(&img2).expect("served after the reset");
    assert_eq!(resp2.logits, oracle(&model, &img2));
    assert!(
        client.reconnects() >= 1,
        "the reset must have forced a redial"
    );
    drop(client);
    finish(srv, net);
}

#[test]
fn overloaded_denials_retry_then_surface_a_bounded_error() {
    // Depth 0: every attempt is shed with Overloaded — retryable, but the
    // retry budget is finite, so the caller gets a typed error, not a loop.
    let (srv, net, model) = start_stack(0, 1 << 20, true);
    let mut client = ResilientClient::new(
        &net.addr().to_string(),
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            ..Default::default()
        },
    );
    let err = client.classify(&image(&model, 0)).expect_err("depth-0 gate");
    assert!(
        format!("{err:#}").contains("denied"),
        "error should carry the denial: {err:#}"
    );
    assert_eq!(
        client.retries(),
        2,
        "exactly max_attempts - 1 retries before surfacing"
    );
    assert_eq!(
        client.reconnects(),
        0,
        "Overloaded keeps the connection — a full reply frame was read"
    );
    drop(client);
    finish(srv, net);
}

#[test]
fn requests_after_drain_fail_bounded_not_hanging() {
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    let addr = net.addr().to_string();
    let mut client = ResilientClient::new(
        &addr,
        RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .with_deadline(Duration::from_secs(5));
    let img = image(&model, 0);
    let resp = client.classify(&img).expect("served before drain");
    assert_eq!(resp.logits, oracle(&model, &img));

    // Drain the front end while the client still holds its connection: the
    // next request must resolve to a bounded typed error (dead socket ->
    // redial -> refused), never hang.
    net.shutdown();
    assert!(client.classify(&image(&model, 1)).is_err());
    assert_eq!(client.retries(), 2, "the retry budget bounds the failure");
    assert_eq!(
        client.reconnects(),
        0,
        "no listener left, so no redial can succeed"
    );
    assert!(srv.stats.drained());
    srv.shutdown();
}

#[test]
fn half_read_reply_then_disconnect_does_not_wedge_the_server() {
    // A client that reads only part of its reply and hangs up must not
    // wedge the writer thread (writes to the dead socket error out and are
    // ignored so ticket accounting completes).
    let (srv, net, model) = start_stack(256, 1 << 20, true);
    {
        let mut raw = TcpStream::connect(net.addr()).expect("connect");
        let img = image(&model, 0);
        let mut req = raw_header(1, 0, img.len() as u32);
        req.extend_from_slice(&img);
        raw.write_all(&req).expect("write");
        raw.flush().expect("flush");
        // Read just one byte of the reply, then vanish.
        let mut one = [0u8; 1];
        raw.read_exact(&mut one).expect("first reply byte");
        assert_eq!(one[0], MAGIC[0]);
    }
    wait_until("half-read conn teardown", || {
        net.stats.open_connections.get() == 0 && net.stats.inflight.get() == 0
    });
    assert!(srv.stats.drained());
    // And the server still serves.
    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let img = image(&model, 2);
    let resp = client.classify(&img).expect("served");
    assert_eq!(resp.logits, oracle(&model, &img));
    drop(client);
    finish(srv, net);
}
