//! Integration tests over real artifacts (skip gracefully when
//! `make artifacts` has not been run — CI correctness still comes from the
//! unit/property tests; these pin the cross-layer contracts).

use onnx2hw::dataflow::{simulate_image, Executor, FoldingConfig};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::mdc;
use onnx2hw::qonnx::Layer;
use onnx2hw::runtime::ArtifactStore;

fn store_or_skip() -> Option<ArtifactStore> {
    match ArtifactStore::discover() {
        Ok(s) => {
            // require at least the A8-W8 artifacts
            if s.qonnx("A8-W8").is_ok() && s.testset().is_ok() {
                Some(s)
            } else {
                eprintln!("skipping: artifacts incomplete");
                None
            }
        }
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

const ALL: [&str; 6] = ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed"];

#[test]
fn rust_dataflow_is_bit_exact_vs_python_vectors() {
    let Some(store) = store_or_skip() else { return };
    let testset = store.testset().unwrap();
    for profile in ALL {
        let (Ok(model), Ok(vectors)) = (store.qonnx(profile), store.vectors(profile)) else {
            eprintln!("skipping {profile}: artifacts missing");
            continue;
        };
        let mut ex = Executor::new(&model);
        for (i, want) in vectors.logits.iter().enumerate() {
            let got = ex.run(testset.image(i));
            assert_eq!(&got, want, "{profile}: image {i} logits diverge from python intref");
        }
    }
}

#[test]
fn streaming_sim_matches_fast_executor_on_real_model() {
    let Some(store) = store_or_skip() else { return };
    let model = store.qonnx("A8-W8").unwrap();
    let testset = store.testset().unwrap();
    let fold = FoldingConfig::default();
    let mut ex = Executor::new(&model);
    for i in 0..3 {
        let img = testset.image(i);
        let rep = simulate_image(&model, &fold, img);
        assert_eq!(rep.logits, ex.run(img), "image {i}");
    }
}

#[test]
fn real_latency_is_precision_independent_table1_invariant() {
    let Some(store) = store_or_skip() else { return };
    let fold = FoldingConfig::default();
    let testset = store.testset().unwrap();
    let img = testset.image(0);
    let mut cycles = std::collections::BTreeSet::new();
    for profile in ["A16-W8", "A8-W8", "A4-W4"] {
        let Ok(model) = store.qonnx(profile) else { continue };
        cycles.insert(simulate_image(&model, &fold, img).cycles);
    }
    assert!(cycles.len() <= 1, "latency differs across precisions: {cycles:?}");
}

#[test]
fn rust_accuracy_matches_python_eval() {
    let Some(store) = store_or_skip() else { return };
    let testset = store.testset().unwrap();
    for profile in ["A8-W8", "A4-W4"] {
        let (Ok(model), Ok(eval)) = (store.qonnx(profile), store.eval(profile)) else {
            continue;
        };
        // python eval is over the whole set; measure a 512-image prefix and
        // allow sampling noise.
        let acc = flow::measure_accuracy(&model, &testset, 512);
        assert!(
            (acc - eval.int_accuracy).abs() < 0.05,
            "{profile}: rust {acc} vs python {}",
            eval.int_accuracy
        );
    }
}

#[test]
fn mdc_merge_of_real_pair_shares_everything_but_inner_conv() {
    let Some(store) = store_or_skip() else { return };
    let (Ok(a), Ok(b)) = (store.qonnx("A8-W8"), store.qonnx("Mixed")) else {
        eprintln!("skipping: pair missing");
        return;
    };
    let fold = FoldingConfig::default();
    let na = mdc::build_network(&a, &fold);
    let nb = mdc::build_network(&b, &fold);
    let md = mdc::merge(&[na.clone(), nb]).unwrap();
    // Mixed = A8-W8 except conv2 (A4-W4): conv2's ConvMac must be duplicated.
    // conv1/pool/dense share. (The conv2 *line buffer* port width changes
    // with the upstream act bits only if conv1 output bits differ — they
    // don't — so it shares too.)
    let dup_slots: Vec<usize> = md
        .instances
        .iter()
        .enumerate()
        .filter(|(_, v)| v.len() > 1)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(dup_slots.len(), 1, "expected only conv2 duplicated: {dup_slots:?}");
    let dup_sig = &md.instances[dup_slots[0]][0];
    assert_eq!(dup_sig.name, "conv2");
    // reconstruction preserves per-profile pipelines
    let pa = md.pipeline_of("A8-W8").unwrap();
    assert_eq!(pa.into_iter().cloned().collect::<Vec<_>>(), na.nodes);
}

#[test]
fn table1_shape_holds() {
    let Some(store) = store_or_skip() else { return };
    let cfg = FlowConfig::default();
    let rows = match flow::table1(
        &store,
        &["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4"],
        &cfg,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let get = |n: &str| rows.iter().find(|r| r.profile == n).unwrap();
    // latency constant
    let lat: std::collections::BTreeSet<u64> = rows.iter().map(|r| r.latency_us as u64).collect();
    assert_eq!(lat.len(), 1, "latency not constant: {lat:?}");
    // LUTs: W8 engines > W4 engines; A16 >= A8 at same W
    assert!(get("A16-W8").lut_pct > get("A16-W4").lut_pct);
    assert!(get("A8-W8").lut_pct > get("A8-W4").lut_pct);
    assert!(get("A16-W8").lut_pct >= get("A8-W8").lut_pct);
    assert!(get("A8-W4").lut_pct >= get("A4-W4").lut_pct);
    // accuracy: W8 engines above W4 engines
    let w8_min = get("A16-W8").accuracy_pct.min(get("A8-W8").accuracy_pct);
    let w4_max = get("A16-W4")
        .accuracy_pct
        .max(get("A8-W4").accuracy_pct)
        .max(get("A4-W4").accuracy_pct);
    assert!(w8_min > w4_max, "W8 accuracy ({w8_min}) not above W4 ({w4_max})");
    // power: every engine in a plausible edge envelope and the W8 flagship
    // costs more than its W4 sibling
    for r in &rows {
        assert!(r.power_mw > 50.0 && r.power_mw < 500.0, "{}: {} mW", r.profile, r.power_mw);
    }
    assert!(get("A16-W8").power_mw > get("A16-W4").power_mw);
}

#[test]
fn qonnx_models_expose_expected_topology() {
    let Some(store) = store_or_skip() else { return };
    let model = store.qonnx("A8-W8").unwrap();
    let kinds: Vec<&str> = model
        .layers
        .iter()
        .map(|l| match l {
            Layer::Conv(_) => "conv",
            Layer::Pool(_) => "pool",
            Layer::Flatten { .. } => "flatten",
            Layer::Dense(_) => "dense",
        })
        .collect();
    assert_eq!(kinds, ["conv", "pool", "conv", "pool", "flatten", "dense"]);
    let convs: Vec<_> = model.conv_layers().collect();
    assert_eq!(convs[0].cout, 64);
    assert_eq!(convs[1].cin, 64);
    assert_eq!(model.dense().unwrap().out_features, 10);
}
