//! PJRT runtime integration: AOT artifacts load, compile, execute, and agree
//! with the integer engine. Requires `make artifacts`; skips otherwise.

use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::{exec, Executor};
use onnx2hw::runtime::{ArtifactStore, PjrtEngine};

fn store_or_skip() -> Option<ArtifactStore> {
    let s = ArtifactStore::discover().ok()?;
    if s.hlo_path("A8-W8", 1).exists() && s.testset().is_ok() {
        Some(s)
    } else {
        eprintln!("skipping: HLO artifacts missing");
        None
    }
}

#[test]
fn pjrt_loads_and_classifies() {
    let Some(store) = store_or_skip() else { return };
    let testset = store.testset().unwrap();
    let mut engine = PjrtEngine::new().unwrap();
    engine.load(&store, "A8-W8", 1).unwrap();
    let (logits, pred) = engine.classify_one("A8-W8", testset.image(0)).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(pred < 10);
    // deterministic across calls
    let (logits2, pred2) = engine.classify_one("A8-W8", testset.image(0)).unwrap();
    assert_eq!(pred, pred2);
    assert_eq!(logits, logits2);
}

#[test]
fn pjrt_agrees_with_integer_engine() {
    let Some(store) = store_or_skip() else { return };
    let testset = store.testset().unwrap();
    let model = store.qonnx("A8-W8").unwrap();
    let mut engine = PjrtEngine::new().unwrap();
    engine.load(&store, "A8-W8", 1).unwrap();
    let mut ex = Executor::new(&model);
    let mut agree = 0;
    let n = 32.min(testset.len());
    for i in 0..n {
        let (_l, pjrt_pred) = engine.classify_one("A8-W8", testset.image(i)).unwrap();
        let int_pred = exec::argmax(&ex.run(testset.image(i)));
        if pjrt_pred == int_pred {
            agree += 1;
        }
    }
    // f32 vs integer rounding can flip near-ties on rare images; demand
    // near-perfect agreement.
    assert!(agree * 100 >= n * 95, "only {agree}/{n} agree");
}

#[test]
fn pjrt_batch8_matches_batch1() {
    let Some(store) = store_or_skip() else { return };
    if !store.hlo_path("A8-W8", 8).exists() {
        eprintln!("skipping: batch-8 artifact missing");
        return;
    }
    let testset = store.testset().unwrap();
    let mut engine = PjrtEngine::new().unwrap();
    engine.load(&store, "A8-W8", 1).unwrap();
    engine.load(&store, "A8-W8", 8).unwrap();
    let imgs: Vec<&[u8]> = (0..8).map(|i| testset.image(i)).collect();
    let batched = engine.classify_batch("A8-W8", &imgs).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let (_l, p1) = engine.classify_one("A8-W8", img).unwrap();
        assert_eq!(batched[i].1, p1, "image {i} batch-vs-single mismatch");
    }
}

#[test]
fn adaptive_server_on_pjrt_backend() {
    let Some(store) = store_or_skip() else { return };
    if !store.hlo_path("Mixed", 1).exists() {
        eprintln!("skipping: Mixed artifact missing");
        return;
    }
    let testset = store.testset().unwrap();
    let specs = vec![
        ProfileSpec {
            name: "A8-W8".into(),
            accuracy: 0.97,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "Mixed".into(),
            accuracy: 0.95,
            power_mw: 135.0,
            latency_us: 329.0,
        },
    ];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    // battery crosses 50% after ~8 requests
    let energy = EnergyMonitor::new(142.0e-3 * 329.0e-6 * 16.0);
    let store2 = store.clone();
    let srv = AdaptiveServer::start(
        ServerConfig::default(),
        move || Backend::pjrt(&store2, &["A8-W8", "Mixed"]),
        manager,
        energy,
    )
    .unwrap();
    let mut profiles = Vec::new();
    for i in 0..24 {
        let resp = srv.classify(testset.image(i % testset.len()).to_vec()).unwrap();
        profiles.push(resp.profile);
    }
    assert!(profiles.iter().any(|p| p == "A8-W8"));
    assert!(profiles.iter().any(|p| p == "Mixed"), "never switched");
    srv.shutdown();
}
