//! Integration suite for the affine error-bound certificates: the
//! committed falsified fixture must be rejected with typed rules against
//! the same synthetic base the CI gate uses, and the explorer's tolerance
//! triage must emit a frontier whose stored certificates survive the
//! load-time re-proof.

use onnx2hw::analysis::{self, Severity, RULE_ERROR_BOUND, RULE_MARGIN_UNSOUND};
use onnx2hw::approx::{CalibSet, Explorer, ExplorerConfig, Frontier};
use onnx2hw::json;
use onnx2hw::qonnx::{
    bound_stress_model_json, random_model_json, read_str, QonnxModel, RandModelCfg,
};
use onnx2hw::testkit::Rng;

/// The `check --synthetic` base model at its default seed (0xA11CE) — the
/// exact model the CI fixture gates run against.
fn synthetic_base() -> QonnxModel {
    let mut rng = Rng::new(659918);
    let cfg = RandModelCfg {
        side: 8,
        cin: 1,
        blocks: vec![(4, 8, 8), (8, 8, 8)],
        classes: 5,
    };
    read_str(&random_model_json(&cfg, &mut rng)).unwrap()
}

#[test]
fn falsified_bound_fixture_is_rejected_with_typed_rules() {
    let base = synthetic_base();
    let text = include_str!("fixtures/falsified_bounds_frontier.json");
    let doc = json::parse(text).unwrap();

    // Fixture premises: the stored config must be legal (so the bound rules
    // — not a config rule — are what reject it), its true deviation must be
    // nonzero (so a stored bound of 0 is genuinely falsified), and the
    // stored acc_narrow must match the proof (so the *bound* rules fire,
    // not staleness).
    let config = [0u32, 1, 0, 0, 0];
    assert!(
        analysis::config_is_legal(&base, &config),
        "fixture config must be legal on the synthetic base"
    );
    let proven = analysis::analyze_error(&base, &config);
    assert!(proven.logit_bound > 0, "an act drop must carry real slack");
    assert!(proven.stable_margin > 0);
    let stored_narrow: Vec<bool> = doc.get("points").unwrap().as_array().unwrap()[0]
        .get("acc_narrow")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|b| b.as_bool().unwrap())
        .collect();
    assert_eq!(
        stored_narrow, proven.conv_narrow,
        "fixture acc_narrow drifted from the proof: regenerate the fixture"
    );

    // `check`-style report: both falsified certificates surface as typed
    // error diagnostics on the point, without failing fast.
    let report = Frontier::check_json(&doc, &base).unwrap();
    assert_eq!(report.len(), 1);
    assert_eq!(report[0].0, "apx-01000");
    let rules: Vec<&str> = report[0]
        .1
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(|d| d.rule)
        .collect();
    assert!(rules.contains(&RULE_ERROR_BOUND), "got rules {rules:?}");
    assert!(rules.contains(&RULE_MARGIN_UNSOUND), "got rules {rules:?}");

    // Loading (the serving path) fails outright.
    let err = Frontier::from_json(&doc, &base).expect_err("falsified fixture must not load");
    let msg = format!("{err:#}");
    assert!(msg.contains("apx-01000"), "must name the point: {msg}");
}

#[test]
fn triaged_frontier_certificates_survive_the_load_time_reproof() {
    // End to end: explore the bound-stress lattice under a logit tolerance,
    // serialize the emitted frontier, and re-load it — every stored
    // certificate must pass the re-proof, survivors must sit within the
    // tolerance, and certified rungs must carry the (0, 0) certificate.
    let model = read_str(&bound_stress_model_json()).unwrap();
    let calib = CalibSet::self_labeled(&model, 16, 0xB0B);
    let mut explorer = Explorer::new(
        &model,
        &calib,
        ExplorerConfig {
            power_images: 1,
            uniform_rungs: 2,
            logit_bound_tolerance: Some(8),
            ..ExplorerConfig::default()
        },
    );
    let frontier = explorer.explore();
    assert!(!frontier.is_empty());
    for p in &frontier.points {
        assert!(
            p.logit_bound <= 8,
            "rung {} emitted above tolerance: {}",
            p.name,
            p.logit_bound
        );
    }
    assert!(
        explorer.skipped_by_bounds() > 0,
        "the even-code lattice must certify some rungs"
    );
    let text = json::to_string_pretty(&frontier.to_json());
    let back = Frontier::from_json(&json::parse(&text).unwrap(), &model)
        .expect("emitted certificates must pass their own re-proof");
    for (a, b) in frontier.points.iter().zip(&back.points) {
        assert_eq!(a.logit_bound, b.logit_bound);
        assert_eq!(a.stable_margin, b.stable_margin);
        assert_eq!(a.acc_narrow, b.acc_narrow);
    }
}
