//! Integration: the approximation explorer's auto-generated ladder, served
//! end to end by the sharded adaptive server.
//!
//! Everything here is seeded and wall-clock free (the PR's determinism
//! contract): a synthetic base model + self-labelled calibration set give
//! the same frontier on every run, and the server walk is driven by a
//! drain-only battery on virtual time.

use std::collections::BTreeMap;

use onnx2hw::approx::{
    config_name, derive_model, knobs_for, CalibSet, Explorer, ExplorerConfig, Frontier,
};
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ServerConfig,
};
use onnx2hw::dataflow::{exec, FoldingConfig};
use onnx2hw::json;
use onnx2hw::qonnx::{random_model_json, read_str, QonnxModel, RandModelCfg};
use onnx2hw::testkit::Rng;

const MODEL_SEED: u64 = 0xD1CE;
const CALIB_SEED: u64 = 0xCAB;
const CALIB_N: usize = 48;

fn base_model() -> QonnxModel {
    let cfg = RandModelCfg {
        side: 8,
        cin: 1,
        blocks: vec![(3, 8, 6), (6, 8, 6)],
        classes: 4,
    };
    read_str(&random_model_json(&cfg, &mut Rng::new(MODEL_SEED))).expect("base model")
}

/// High parallelism keeps the per-candidate actor simulation cheap so the
/// whole exploration stays test-suite friendly.
fn explorer_cfg() -> ExplorerConfig {
    ExplorerConfig {
        fold: FoldingConfig {
            conv1_pe: 64,
            conv1_simd: 64,
            conv2_pe: 64,
            conv2_simd: 576,
            dense_pe: 16,
            dense_simd: 64,
            fifo_depth: 8,
        },
        power_images: 1,
        uniform_rungs: 3,
        ..Default::default()
    }
}

fn explore() -> (QonnxModel, CalibSet, Frontier) {
    let model = base_model();
    let calib = CalibSet::self_labeled(&model, CALIB_N, CALIB_SEED);
    let mut explorer = Explorer::new(&model, &calib, explorer_cfg());
    let frontier = explorer.explore();
    (model, calib, frontier)
}

#[test]
fn explorer_runs_are_reproducible() {
    let (model, calib, first) = explore();
    let mut explorer = Explorer::new(&model, &calib, explorer_cfg());
    let second = explorer.explore();
    assert_eq!(first.len(), second.len(), "same seeds must give the same ladder");
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.config, b.config);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.energy_uj, b.energy_uj);
    }
}

#[test]
fn frontier_covers_baseline_and_round_trips() {
    let (model, calib, frontier) = explore();
    assert!(
        frontier.len() >= 4,
        "expected a multi-rung ladder, got {} rungs",
        frontier.len()
    );
    // the top rung carries the fidelity-exact accuracy (the root config is
    // always in the archive, so the ladder tops out at 1.0)
    assert_eq!(frontier.points[0].accuracy, 1.0);
    // seeded uniform baseline rungs are always weakly covered
    let mut explorer = Explorer::new(&model, &calib, explorer_cfg());
    explorer.explore();
    for b in explorer.uniform_baseline() {
        assert!(frontier.weakly_dominates(b.accuracy, b.energy_uj, b.latency_us));
    }
    // JSON round trip through the vendored json module, models re-derived
    let text = json::to_string_pretty(&frontier.to_json());
    let back = Frontier::from_json(&json::parse(&text).unwrap(), &model).unwrap();
    assert_eq!(back.len(), frontier.len());
    for (a, b) in frontier.points.iter().zip(&back.points) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.energy_uj, b.energy_uj);
    }
}

#[test]
fn derived_rungs_match_their_configs() {
    let (model, _, frontier) = explore();
    for p in &frontier.points {
        assert_eq!(p.name, config_name(&p.config));
        assert_eq!(p.model, derive_model(&model, &p.config, &p.name));
        assert_eq!(p.config.len(), knobs_for(&model).len());
    }
}

#[test]
fn coordinator_serves_the_auto_generated_ladder_bit_exactly() {
    // The acceptance path: explorer frontier -> ProfileManager::from_frontier
    // + Backend::sim_from_models -> AdaptiveServer. Under a drain-only
    // battery the shard must walk down the ladder monotonically and every
    // reply must be bit-exact vs the scalar oracle of its *selected* rung.
    let (_, calib, frontier) = explore();
    let models = frontier.models();
    let oracle: BTreeMap<String, QonnxModel> = models.clone();
    let manager = ProfileManager::from_frontier(
        ManagerConfig {
            low_energy_threshold: 0.6,
            hysteresis: 0.01,
            accuracy_floor: 0.0,
        },
        &frontier,
    );
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    const REQUESTS: usize = 600;
    let top = &frontier.points[0];
    // sized to deplete mid-run: the walk is forced through every band
    let capacity_j = top.power_mw * 1e-3 * top.latency_us * 1e-6 * REQUESTS as f64 / 4.0;
    let srv = AdaptiveServer::start(
        ServerConfig::default(),
        factory,
        manager,
        EnergyMonitor::new(capacity_j),
    )
    .expect("server");

    let rung_of = |name: &str| frontier.points.iter().position(|p| p.name == name).unwrap();
    let mut prev = 0usize;
    let mut distinct: Vec<String> = Vec::new();
    for i in 0..REQUESTS {
        let img = &calib.images[i % calib.images.len()];
        let resp = srv.classify(img.clone()).expect("reply lost");
        let want: Vec<f32> = exec::execute(&oracle[&resp.profile], img)
            .iter()
            .map(|&v| v as f32)
            .collect();
        assert_eq!(
            resp.logits, want,
            "request {i}: reply not bit-exact vs rung '{}'",
            resp.profile
        );
        let rung = rung_of(&resp.profile);
        assert!(rung >= prev, "drain-only walk went back up: {prev} -> {rung}");
        prev = rung;
        if distinct.last() != Some(&resp.profile) {
            distinct.push(resp.profile);
        }
    }
    assert!(
        distinct.len() >= 3,
        "expected the walk to serve >= 3 distinct rungs, got {distinct:?}"
    );
    assert!(srv.shard_energy[0].depleted(), "battery must deplete mid-run");
    assert_eq!(
        prev,
        frontier.len() - 1,
        "a dead battery must end on the cheapest rung"
    );
    srv.shutdown();
}
