"""Quantization-aware training (Sect. 4.1): Adam + categorical cross-entropy.

QKeras substitute (DESIGN.md §2): straight-through-estimator fake-quant QAT
in JAX, per-profile. Adam is implemented in-house (no optax in this
environment). One model is trained per execution profile; checkpoints land
in artifacts/ckpt_<profile>.npz together with the profile's QAT test
accuracy, so `make artifacts` only retrains when inputs change.

Usage:  python -m compile.train [--profiles A8-W8,Mixed] [--epochs 4]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model
from .profiles import ALL, BY_NAME

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, opt, lr):
    t = opt["t"] + 1
    m = jax.tree.map(lambda m, g: ADAM_B1 * m + (1 - ADAM_B1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v, g: ADAM_B2 * v + (1 - ADAM_B2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - ADAM_B1 ** t)
    vhat_scale = 1.0 / (1 - ADAM_B2 ** t)
    params = jax.tree.map(
        lambda p, m, v: p - lr * (m * mhat_scale) /
        (jnp.sqrt(v * vhat_scale) + ADAM_EPS),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(labels.shape[0]), labels].mean()


def make_step(profile, lr):
    def loss_fn(params, state, x, y):
        logits, new_state = model.qat_forward(params, state, x, profile,
                                              train=True)
        return cross_entropy(logits, y), new_state

    @jax.jit
    def step(params, state, opt, x, y):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, x, y)
        params, opt = adam_update(params, grads, opt, lr)
        return params, new_state, opt, loss

    return step


def evaluate(params, state, profile, x, y, batch=256):
    @jax.jit
    def fwd(xb):
        logits, _ = model.qat_forward(params, state, xb, profile, train=False)
        return logits.argmax(axis=1)

    correct = 0
    for i in range(0, len(y), batch):
        correct += int((fwd(x[i:i + batch]) == y[i:i + batch]).sum())
    return correct / len(y)


def train_profile(profile, data, epochs=4, batch=64, lr=1e-3, seed=0,
                  log=print, init=None, trainable=None):
    """Train one profile.

    init: optional (params, state) to start from (Sect. 4.3: the Mixed
    profile is derived from the trained A8-W8 engine).
    trainable: optional set of top-level param keys to update; all other
    parameters (and their BN running stats) stay frozen at `init` — this is
    what keeps the shared layers bit-identical so MDC can share their
    hardware actors AND weight ROMs.
    """
    x_train, y_train, x_test, y_test = data
    if init is not None:
        params, state = jax.tree.map(jnp.asarray, init[0]), jax.tree.map(
            jnp.asarray, init[1])
    else:
        params = model.init_params(seed)
        state = model.init_bn_state()
    frozen_params = None
    if trainable is not None:
        frozen_params = {k: v for k, v in params.items() if k not in trainable}
        frozen_state = {k: v for k, v in state.items()
                        if k not in {t.replace("conv", "bn") for t in trainable}}
    opt = adam_init(params)
    step = make_step(profile, lr)

    n = len(y_train)
    rng = np.random.default_rng(seed + 1)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        t0 = time.time()
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            params, state, opt, loss = step(
                params, state, opt, x_train[idx], y_train[idx])
            if frozen_params is not None:
                params = {**params, **frozen_params}
                state = {**state, **frozen_state}
            losses.append(float(loss))
        acc = evaluate(params, state, profile, x_test, y_test)
        log(f"  [{profile.name}] epoch {epoch + 1}/{epochs} "
            f"loss={np.mean(losses):.4f} test_acc={acc:.4f} "
            f"({time.time() - t0:.1f}s)")
    return params, state, acc


def save_ckpt(path, params, state, acc, profile_name):
    flat = {}

    def put(prefix, tree):
        for k, v in tree.items():
            if isinstance(v, dict):
                put(f"{prefix}{k}/", v)
            else:
                flat[f"{prefix}{k}"] = np.asarray(v)

    put("params/", params)
    put("state/", state)
    flat["meta/qat_accuracy"] = np.float64(acc)
    np.savez(path, **flat)


def load_ckpt(path):
    data = np.load(path)
    params, state = {}, {}

    def unflatten(root, key, val):
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(val)

    acc = None
    for k in data.files:
        if k == "meta/qat_accuracy":
            acc = float(data[k])
        elif k.startswith("params/"):
            unflatten(params, k[len("params/"):], data[k])
        elif k.startswith("state/"):
            unflatten(state, k[len("state/"):], data[k])
    return params, state, acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profiles", default=",".join(p.name for p in ALL))
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-train", type=int, default=4096)
    ap.add_argument("--n-test", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    print(f"generating synthetic-MNIST ({args.n_train} train / {args.n_test} test)")
    data = dataset.make_dataset(args.n_train, args.n_test, args.seed)
    data = tuple(jnp.asarray(d) for d in data)

    results = {}
    for name in args.profiles.split(","):
        profile = BY_NAME[name.strip()]
        ckpt = os.path.join(args.out, f"ckpt_{profile.name}.npz")
        if args.skip_existing and os.path.exists(ckpt):
            _, _, acc = load_ckpt(ckpt)
            print(f"skipping {profile.name} (exists, acc={acc:.4f})")
            results[profile.name] = acc
            continue
        init, trainable = None, None
        if profile.name == "Mixed":
            # Sect. 4.3: Mixed is derived from the trained A8-W8 profile;
            # only the inner conv block adapts to its reduced precision, so
            # conv1/dense (and bn1) remain shared with A8-W8 — the layers
            # MDC merges in the adaptive engine.
            base = os.path.join(args.out, "ckpt_A8-W8.npz")
            if os.path.exists(base):
                p0, s0, _ = load_ckpt(base)
                init = (p0, s0)
                trainable = {"conv2", "bn2"}
                print("  Mixed: fine-tuning conv2/bn2 from A8-W8 checkpoint")
        print(f"training {profile.name} -> {ckpt}")
        params, state, acc = train_profile(
            profile, data, epochs=args.epochs, batch=args.batch,
            seed=args.seed, init=init, trainable=trainable)
        save_ckpt(ckpt, params, state, acc, profile.name)
        results[profile.name] = acc

    with open(os.path.join(args.out, "qat_accuracy.json"), "w") as f:
        json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
