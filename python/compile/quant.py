"""Fixed-point fake quantization with straight-through estimators (STE).

Mirrors the paper's data-approximation scheme: Vitis HLS `ap_fixed`-style
arbitrary-precision fixed point, with per-layer bit-widths for activations
(Ax) and weights (Wy). Semantics:

* Activations (post-ReLU, unsigned): `ufixed<bits, int_bits>` — values on the
  grid step = 2^(int_bits - bits), clipped to [0, 2^int_bits - step].
* Weights (signed, symmetric): per-channel (convs) or per-tensor (dense)
  scale derived from the running max-abs; values on grid step = s/2^(bits-1).

Both return *float* tensors lying exactly on the quantization grid — QAT runs
in the scaled-real domain; the rust dataflow simulator runs the same network
in the integer-code domain (see export.py for the bridging).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ste(x_q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: value of x_q, gradient of x."""
    return x + jax.lax.stop_gradient(x_q - x)


def quantize_act(x: jnp.ndarray, bits: int, int_bits: int = 2) -> jnp.ndarray:
    """Unsigned fixed-point activation quantization with built-in ReLU clip.

    ufixed<bits, int_bits>: grid step 2^(int_bits-bits), range [0, 2^int_bits).
    Gradient passes straight through inside the clip range.
    """
    step = 2.0 ** (int_bits - bits)
    qmax = 2.0 ** bits - 1.0
    q = jnp.clip(jnp.round(x / step), 0.0, qmax) * step
    return _ste(q, jnp.clip(x, 0.0, qmax * step))


def quantize_weight(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric signed weight quantization on a *fixed* power-of-two grid.

    QKeras `quantized_bits(bits, 0, alpha=1)` semantics (the paper trains
    with QKeras): grid step 2^(1-bits), representable range
    [-(2^(b-1)-1)*step, +(2^(b-1)-1)*step] ~= (-1, 1). No per-tensor
    calibration — this fixed grid is what makes 4-bit weights genuinely
    lossy (the paper's Table 1: W4 ~ 95% vs W8 ~ 99%).
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    step = 2.0 ** (1 - bits)
    q = jnp.clip(jnp.round(w / step), -qmax, qmax) * step
    return _ste(q, jnp.clip(w, -1.0, 1.0))


def weight_step(bits: int) -> float:
    """Grid step of `quantize_weight(bits)`."""
    return 2.0 ** (1 - bits)


def weight_codes(w, bits: int):
    """Integer codes on the fixed po2 grid (numpy, no STE) for export."""
    import numpy as np

    w = np.asarray(w)
    qmax = 2.0 ** (bits - 1) - 1.0
    step = weight_step(bits)
    return np.clip(np.round(w / step), -qmax, qmax).astype(np.int32)


def act_step(bits: int, int_bits: int = 2) -> float:
    """Grid step of `quantize_act(bits, int_bits)`."""
    return 2.0 ** (int_bits - bits)


def requant_multiplier(real_mult: float, mult_bits: int = 15):
    """Fixed-point (M, rshift) such that x * real_mult ~= (x * M) >> rshift.

    This is the TFLite-style requantization bridge used by the rust integer
    pipeline: the float scale ratio (sx * sw_c / sy) becomes an int multiplier
    M (< 2^mult_bits) plus a right shift with round-half-up.
    """
    import math

    if real_mult <= 0.0:
        return 0, 0
    # Normalize real_mult = m * 2^e with m in [0.5, 1).
    m, e = math.frexp(real_mult)
    M = int(round(m * (1 << mult_bits)))
    rshift = mult_bits - e
    if M == (1 << mult_bits):  # rounding overflow
        M >>= 1
        rshift -= 1
    # Clamp pathological shifts (extremely small/large scales).
    if rshift < 0:
        M <<= -rshift
        rshift = 0
    return M, rshift
