"""Export trained profiles to the QONNX interchange consumed by rust.

Per profile this emits:
  artifacts/model_<p>.qonnx.json   — QONNX-as-JSON: graph topology, layer
                                     hyper-parameters, quantization metadata,
                                     integer weights (DESIGN.md §2: protobuf
                                     is an encoding detail; the JSON carries
                                     the same information, and rust ships a
                                     full JSON parser substrate).
  artifacts/eval_<p>.json          — integer-pipeline test accuracy + the
                                     per-layer scales (Table 1 accuracy col).
Shared:
  artifacts/testset.bin            — N x 28 x 28 u8 input codes
  artifacts/testset.json           — labels + metadata
  artifacts/vectors_<p>.json       — 64 images' integer logits (bit-exact
                                     pin between intref.py and rust dataflow)

Schema of model_<p>.qonnx.json (version 1):
{
  "qonnx_version": 1, "profile": "A8-W8",
  "input": {"shape": [1,28,28,1], "bits": 8, "int_bits": 0},
  "nodes": [
    {"name":"conv1","op":"QConv2d","inputs":["input"],"outputs":["conv1_out"],
     "attrs":{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":64,
              "act_bits":8,"act_int_bits":2,"weight_bits":8},
     "weights":{"w_codes":[...],"w_shape":[3,3,1,64],"b_codes":[...],
                "mult":[...],"shift":[...]}},
    {"name":"pool1","op":"MaxPool2","inputs":["conv1_out"], ...},
    ...,
    {"name":"dense","op":"QGemm", ...}
  ],
  "output": "logits"
}
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from . import dataset, intref, model, train
from .profiles import ALL, BY_NAME, INPUT_BITS, INPUT_INT_BITS


def qonnx_dict(im: intref.IntModel) -> dict:
    """Serialize an IntModel to the QONNX-JSON schema (version 1)."""

    def conv_node(name, layer: intref.IntConv, inp, out):
        return {
            "name": name,
            "op": "QConv2d",
            "inputs": [inp],
            "outputs": [out],
            "attrs": {
                "kernel": [3, 3], "stride": [1, 1], "pad": "SAME",
                "filters": int(layer.w_codes.shape[-1]),
                "in_channels": int(layer.w_codes.shape[-2]),
                "act_bits": layer.act_bits,
                "act_int_bits": 2,
                "weight_bits": layer.weight_bits,
            },
            "weights": {
                "w_shape": list(layer.w_codes.shape),
                "w_codes": layer.w_codes.flatten().tolist(),
                "b_codes": layer.b_codes.tolist(),
                "mult": layer.mult.tolist(),
                "shift": layer.shift.tolist(),
                "w_step": np.asarray(layer.w_step).tolist(),
                "in_step": layer.in_step,
                "out_step": layer.out_step,
            },
        }

    def pool_node(name, inp, out):
        return {"name": name, "op": "MaxPool2", "inputs": [inp],
                "outputs": [out], "attrs": {"kernel": [2, 2], "stride": [2, 2]}}

    dense = im.dense
    nodes = [
        conv_node("conv1", im.conv1, "input", "conv1_out"),
        pool_node("pool1", "conv1_out", "pool1_out"),
        conv_node("conv2", im.conv2, "pool1_out", "conv2_out"),
        pool_node("pool2", "conv2_out", "pool2_out"),
        {"name": "flatten", "op": "Flatten", "inputs": ["pool2_out"],
         "outputs": ["flat_out"], "attrs": {}},
        {"name": "dense", "op": "QGemm", "inputs": ["flat_out"],
         "outputs": ["logits"],
         "attrs": {"in_features": int(dense.w_codes.shape[0]),
                   "out_features": int(dense.w_codes.shape[1]),
                   "weight_bits": dense.weight_bits,
                   # raw accumulator output (no requant on the head)
                   "act_bits": 0, "act_int_bits": 0},
         "weights": {"w_shape": list(dense.w_codes.shape),
                     "w_codes": dense.w_codes.flatten().tolist(),
                     "b_codes": dense.b_codes.tolist(),
                     "w_step": dense.w_step,
                     "in_step": dense.in_step}},
    ]
    return {
        "qonnx_version": 1,
        "profile": im.profile_name,
        "input": {"shape": [1, 28, 28, 1], "bits": INPUT_BITS,
                  "int_bits": INPUT_INT_BITS},
        "nodes": nodes,
        "output": "logits",
    }


def export_profile(name: str, out_dir: str, x_test_u8, y_test,
                   n_vectors: int = 64) -> dict:
    profile = BY_NAME[name]
    params, state, qat_acc = train.load_ckpt(
        os.path.join(out_dir, f"ckpt_{name}.npz"))
    im = intref.quantize_model(params, state, profile, bn_eps=model.BN_EPS)

    # QONNX JSON
    with open(os.path.join(out_dir, f"model_{name}.qonnx.json"), "w") as f:
        json.dump(qonnx_dict(im), f)

    # Integer-pipeline accuracy (the engine accuracy reported in Table 1).
    acc = intref.accuracy(im, x_test_u8, y_test)

    # Bit-exact test vectors for the rust dataflow engine.
    logits = intref.run(im, x_test_u8[:n_vectors])
    with open(os.path.join(out_dir, f"vectors_{name}.json"), "w") as f:
        json.dump({"profile": name, "n": n_vectors,
                   "logits": logits.tolist(),
                   "pred": logits.argmax(axis=1).tolist()}, f)

    ev = {"profile": name, "int_accuracy": acc, "qat_accuracy": qat_acc,
          "n_test": int(len(y_test))}
    with open(os.path.join(out_dir, f"eval_{name}.json"), "w") as f:
        json.dump(ev, f, indent=2)
    return ev


def export_testset(out_dir: str, n_train: int, n_test: int, seed: int):
    """Write the shared test set (u8 codes + labels)."""
    _, _, x_test, y_test = dataset.make_dataset(n_train, n_test, seed)
    codes = dataset.input_codes(x_test)             # (N,28,28,1) u8
    with open(os.path.join(out_dir, "testset.bin"), "wb") as f:
        f.write(codes.tobytes())
    with open(os.path.join(out_dir, "testset.json"), "w") as f:
        json.dump({"n": int(n_test), "height": 28, "width": 28, "channels": 1,
                   "labels": y_test.tolist()}, f)
    return codes, y_test


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profiles", default=",".join(p.name for p in ALL))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n-train", type=int, default=4096)
    ap.add_argument("--n-test", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    codes, y_test = export_testset(args.out, args.n_train, args.n_test,
                                   args.seed)
    results = {}
    for name in args.profiles.split(","):
        ev = export_profile(name.strip(), args.out, codes, y_test)
        results[ev["profile"]] = ev
        print(f"{ev['profile']}: int_acc={ev['int_accuracy']:.4f} "
              f"(qat {ev['qat_accuracy']:.4f})")
    with open(os.path.join(args.out, "eval_all.json"), "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    main()
