"""Synthetic MNIST-like dataset (offline substitute for MNIST).

The paper evaluates a tiny CNN on MNIST classification. This environment is
offline, so we substitute a procedurally generated 10-class digit dataset
with the same tensor shapes (28x28x1, values in [0, 1)): 5x7 glyph bitmaps
are upscaled and placed with random affine jitter (shift / scale / shear),
random stroke intensity, blur, and additive Gaussian noise.

The substitution is documented in DESIGN.md §2 — what matters for the
reproduction is the *trend* of accuracy vs. data precision (W8 ~ 99%,
W4 ~ 95%), which requires a learnable-but-not-trivial 10-class task. The
jitter/noise knobs below are tuned so a float model reaches ~99.8% (the
paper's float baseline) while 4-bit-weight models lose a few percent.
"""

from __future__ import annotations

import numpy as np

# Classic 5x7 dot-matrix font for digits 0-9. Each glyph is 7 rows of 5 bits,
# MSB = leftmost pixel.
_FONT_5X7 = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

IMG = 28  # image side; matches MNIST


def _glyph(digit: int) -> np.ndarray:
    rows = _FONT_5X7[digit]
    g = np.array([[1.0 if c == "1" else 0.0 for c in row] for row in rows],
                 dtype=np.float32)
    return g  # (7, 5)


def _bilinear_sample(img: np.ndarray, ys: np.ndarray, xs: np.ndarray) -> np.ndarray:
    """Sample `img` at float coords (ys, xs) with bilinear interp, zero pad."""
    h, w = img.shape
    y0 = np.floor(ys).astype(np.int32)
    x0 = np.floor(xs).astype(np.int32)
    dy = ys - y0
    dx = xs - x0

    def at(yi, xi):
        valid = (yi >= 0) & (yi < h) & (xi >= 0) & (xi < w)
        yi_c = np.clip(yi, 0, h - 1)
        xi_c = np.clip(xi, 0, w - 1)
        return np.where(valid, img[yi_c, xi_c], 0.0)

    return ((1 - dy) * (1 - dx) * at(y0, x0)
            + (1 - dy) * dx * at(y0, x0 + 1)
            + dy * (1 - dx) * at(y0 + 1, x0)
            + dy * dx * at(y0 + 1, x0 + 1)).astype(np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 28x28 image of `digit` with random affine + noise."""
    g = _glyph(digit)

    # Random affine: scale, rotation-ish shear, translation.
    scale = rng.uniform(2.0, 3.4)            # glyph pixel -> image pixels
    shear = rng.uniform(-0.35, 0.35)
    angle = rng.uniform(-0.45, 0.45)         # radians
    tx = rng.uniform(-4.0, 4.0)
    ty = rng.uniform(-4.0, 4.0)

    ca, sa = np.cos(angle), np.sin(angle)
    # Target-to-source mapping (inverse warp): centre both frames.
    yy, xx = np.meshgrid(np.arange(IMG, dtype=np.float32),
                         np.arange(IMG, dtype=np.float32), indexing="ij")
    cy, cx = IMG / 2 + ty, IMG / 2 + tx
    u = (xx - cx) / scale
    v = (yy - cy) / scale
    # inverse rotate + shear
    us = ca * u + sa * v
    vs = -sa * u + ca * v
    us = us + shear * vs
    src_x = us + 2.5   # glyph centre (5 wide)
    src_y = vs + 3.5   # glyph centre (7 tall)

    img = _bilinear_sample(g, src_y, src_x)

    # Stroke intensity + light blur (3x3 box, weighted) + noise.
    intensity = rng.uniform(0.55, 1.0)
    img = img * intensity
    k = rng.uniform(0.05, 0.20)
    blurred = img.copy()
    blurred[1:-1, 1:-1] = (1 - 4 * k) * img[1:-1, 1:-1] + k * (
        img[:-2, 1:-1] + img[2:, 1:-1] + img[1:-1, :-2] + img[1:-1, 2:])
    img = blurred
    # Random occluding strip (simulates sensor dropout) + stronger noise.
    if rng.uniform() < 0.25:
        r = rng.integers(0, IMG - 2)
        if rng.uniform() < 0.5:
            img[r:r + 2, :] = 0.0
        else:
            img[:, r:r + 2] = 0.0
    img = img + rng.normal(0.0, 0.08, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 0.999).astype(np.float32)


def make_dataset(n_train: int = 8192, n_test: int = 2048, seed: int = 0):
    """Return (x_train, y_train, x_test, y_test).

    Images are float32 in [0, 1), shape (N, 28, 28, 1); labels int32.
    Deterministic for a given seed.
    """
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render(int(d), rng) for d in labels])[..., None]
    return (imgs[:n_train], labels[:n_train],
            imgs[n_train:], labels[n_train:])


def quantize_input(x: np.ndarray) -> np.ndarray:
    """Input layer quantization: unsigned 8-bit fixed point in [0,1), step 1/256.

    Returns float values on the quantization grid (q / 256). The rust
    dataflow simulator consumes the raw u8 codes (see export.py).
    """
    return np.clip(np.floor(x * 256.0), 0, 255).astype(np.float32) / 256.0


def input_codes(x: np.ndarray) -> np.ndarray:
    """u8 integer codes of the quantized input (for the rust simulator)."""
    return np.clip(np.floor(x * 256.0), 0, 255).astype(np.uint8)
