"""Execution profiles: the paper's mixed-precision configurations.

A profile is named `Ax-Wy` (x activation bits, y weight bits) following
Sect. 4.2 of the paper, plus the `Mixed` profile of Sect. 4.3 (same as A8-W8
except the inner convolutional layer, which runs at A4-W4).

Each profile assigns (act_bits, weight_bits) to the three parametric layers:
conv1, conv2 (the "inner" conv), dense. Activation int_bits is fixed at 2
(ufixed<b,2>, range [0,4)) for hidden layers; the input is ufixed<8,0>.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LayerPrec:
    act_bits: int      # bits of the layer's *output* activation quantizer
    weight_bits: int
    act_int_bits: int = 2


@dataclass(frozen=True)
class Profile:
    name: str
    conv1: LayerPrec
    conv2: LayerPrec
    dense: LayerPrec

    def layers(self):
        return {"conv1": self.conv1, "conv2": self.conv2, "dense": self.dense}


def uniform(name: str, a: int, w: int) -> Profile:
    p = LayerPrec(a, w)
    return Profile(name, p, p, p)


# The five Table-1 profiles.
TABLE1 = [
    uniform("A16-W8", 16, 8),
    uniform("A16-W4", 16, 4),
    uniform("A8-W8", 8, 8),
    uniform("A8-W4", 8, 4),
    uniform("A4-W4", 4, 4),
]

# Sect. 4.3: Mixed = A8-W8 with the inner conv at A4-W4.
MIXED = Profile("Mixed", LayerPrec(8, 8), LayerPrec(4, 4), LayerPrec(8, 8))

ALL = TABLE1 + [MIXED]

BY_NAME = {p.name: p for p in ALL}

# The two profiles merged into the adaptive engine (Sect. 4.4).
ADAPTIVE_PAIR = ("A8-W8", "Mixed")

INPUT_BITS = 8       # ufixed<8,0> input pixels
INPUT_INT_BITS = 0
