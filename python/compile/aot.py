"""AOT: lower per-profile inference functions to HLO text for the rust runtime.

HLO *text* is the interchange format (NOT `lowered.compile()` /
`.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Per profile we emit `artifacts/model_<p>.hlo.txt`: the folded fake-quant
inference graph (through the L1 Pallas kernels, interpret=True so they lower
to portable HLO) with the trained weights baked in as constants. Input:
f32[batch,28,28,1] quantized pixels; output: tuple(f32[batch,10]) logits.

Batch variants: batch=1 (latency path) and batch=8 (the rust dynamic batcher
coalesces up to 8 requests — `model_<p>_b8.hlo.txt`).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .profiles import ALL, BY_NAME

BATCH_VARIANTS = (1, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_profile(name: str, out_dir: str, batch: int,
                  use_pallas: bool = True) -> str:
    profile = BY_NAME[name]
    params, state, _ = train.load_ckpt(
        os.path.join(out_dir, f"ckpt_{name}.npz"))
    folded = model.fold_bn(params, state, profile)
    folded = jax.tree.map(jnp.asarray, folded)

    def infer(x):
        return (model.infer_float(folded, x, profile, use_pallas=use_pallas),)

    spec = jax.ShapeDtypeStruct((batch, 28, 28, 1), jnp.float32)
    lowered = jax.jit(infer).lower(spec)
    text = to_hlo_text(lowered)
    suffix = "" if batch == 1 else f"_b{batch}"
    path = os.path.join(out_dir, f"model_{name}{suffix}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profiles", default=",".join(p.name for p in ALL))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp graph instead of Pallas kernels")
    args = ap.parse_args()

    for name in args.profiles.split(","):
        for batch in BATCH_VARIANTS:
            path = lower_profile(name.strip(), args.out, batch,
                                 use_pallas=not args.no_pallas)
            size = os.path.getsize(path)
            print(f"wrote {path} ({size / 1e6:.2f} MB)")


if __name__ == "__main__":
    main()
