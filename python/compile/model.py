"""L2: the paper's tiny CNN (Sect. 4) in JAX, with mixed-precision QAT.

Architecture (Sect. 4 of the paper): two convolutional blocks — conv 3x3,
64 filters, batch-norm, ReLU (the ReLU is fused into the unsigned activation
quantizer), 2x2 max-pool — followed by a fully-connected layer with 10
outputs. Input 28x28x1 in [0,1).

Two forwards:
  * `qat_forward`    — training-time graph: fake-quant weights (per-channel),
                       batch-norm with batch stats, fake-quant activations.
  * `infer_float`    — inference graph with BN folded into the conv weights;
                       this is what `aot.py` lowers to HLO (optionally through
                       the Pallas kernels so they land in the same HLO).

The integer-exact twin of `infer_float` lives in `intref.py`; the rust
dataflow simulator implements the same integer pipeline bit-exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .profiles import INPUT_BITS, INPUT_INT_BITS, Profile
from .kernels import conv2d as k_conv, dense as k_dense, pool as k_pool
from .kernels import quantize as k_quant, ref

BN_EPS = 1e-3
BN_MOMENTUM = 0.9

CONV_FILTERS = 64
NUM_CLASSES = 10


def init_params(seed: int = 0) -> dict:
    """He-normal initialised parameters + BN affine."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), 3)
    f = CONV_FILTERS

    def he(rng, shape):
        fan_in = int(np.prod(shape[:-1]))
        return jax.random.normal(rng, shape, jnp.float32) * np.sqrt(2.0 / fan_in)

    return {
        "conv1": {"w": he(rngs[0], (3, 3, 1, f)), "b": jnp.zeros((f,))},
        "bn1": {"gamma": jnp.ones((f,)), "beta": jnp.zeros((f,))},
        "conv2": {"w": he(rngs[1], (3, 3, f, f)), "b": jnp.zeros((f,))},
        "bn2": {"gamma": jnp.ones((f,)), "beta": jnp.zeros((f,))},
        "dense": {"w": he(rngs[2], (f * 7 * 7, NUM_CLASSES)),
                  "b": jnp.zeros((NUM_CLASSES,))},
    }


def init_bn_state() -> dict:
    f = CONV_FILTERS
    return {
        "bn1": {"mean": jnp.zeros((f,)), "var": jnp.ones((f,))},
        "bn2": {"mean": jnp.zeros((f,)), "var": jnp.ones((f,))},
    }


def _bn(h, gamma, beta, mean, var):
    return gamma * (h - mean) * jax.lax.rsqrt(var + BN_EPS) + beta


def qat_forward(params: dict, state: dict, x: jnp.ndarray, profile: Profile,
                train: bool):
    """Training-time fake-quant forward. Returns (logits, new_state)."""
    new_state = {}
    x = quant.quantize_act(x, INPUT_BITS, INPUT_INT_BITS)

    h = x
    for name, bn_name in (("conv1", "bn1"), ("conv2", "bn2")):
        prec = profile.layers()[name]
        wq = quant.quantize_weight(params[name]["w"], prec.weight_bits)
        h = ref.conv2d_3x3(h, wq, params[name]["b"])
        if train:
            mean = h.mean(axis=(0, 1, 2))
            var = h.var(axis=(0, 1, 2))
            run = state[bn_name]
            new_state[bn_name] = {
                "mean": BN_MOMENTUM * run["mean"] + (1 - BN_MOMENTUM) * mean,
                "var": BN_MOMENTUM * run["var"] + (1 - BN_MOMENTUM) * var,
            }
        else:
            mean, var = state[bn_name]["mean"], state[bn_name]["var"]
            new_state[bn_name] = state[bn_name]
        h = _bn(h, params[bn_name]["gamma"], params[bn_name]["beta"], mean, var)
        h = quant.quantize_act(h, prec.act_bits, prec.act_int_bits)  # ReLU+quant
        h = ref.maxpool2(h)

    h = h.reshape(h.shape[0], -1)
    prec = profile.dense
    wq = quant.quantize_weight(params["dense"]["w"], prec.weight_bits)
    logits = ref.dense(h, wq, params["dense"]["b"])
    return logits, new_state


def fold_bn(params: dict, state: dict, profile: Profile) -> dict:
    """Fold BN (running stats) *around* the quantized conv weights.

    QAT evaluates  BN(conv(x, Wq) + b)  with Wq on the fixed po2 grid, so the
    inference graph must be  conv(x, g*Wq) + (g*b + t)  — the quantization
    happens BEFORE the fold (codes are preserved; the per-channel gain g
    moves into the requantization scale, exactly as intref.py does on the
    integer side). g = gamma / sqrt(var + eps), t = beta - g * mean.
    """
    folded = {}
    for name, bn_name in (("conv1", "bn1"), ("conv2", "bn2")):
        prec = profile.layers()[name]
        gamma = params[bn_name]["gamma"]
        beta = params[bn_name]["beta"]
        mean = state[bn_name]["mean"]
        var = state[bn_name]["var"]
        g = gamma / jnp.sqrt(var + BN_EPS)
        wq = quant.quantize_weight(params[name]["w"], prec.weight_bits)
        folded[name] = {
            "w": wq * g,                         # broadcast over Cout
            "b": g * params[name]["b"] + (beta - g * mean),
        }
    folded["dense"] = {
        "w": quant.quantize_weight(params["dense"]["w"], profile.dense.weight_bits),
        "b": params["dense"]["b"],
    }
    return folded


def infer_float(folded: dict, x: jnp.ndarray, profile: Profile,
                use_pallas: bool = True) -> jnp.ndarray:
    """Inference graph (BN folded, pre-quantized weights + fake-quant acts).

    `folded` comes from `fold_bn` (weights already on the quantization grid,
    scaled by the BN gain). With use_pallas=True every op goes through the
    L1 Pallas kernels, so the lowered HLO contains the kernels' schedule.
    Numerics match intref.py's integer pipeline up to f32 rounding
    (argmax-identical in practice).
    """
    conv = k_conv.conv2d_3x3 if use_pallas else ref.conv2d_3x3
    pool = k_pool.maxpool2 if use_pallas else ref.maxpool2
    dens = k_dense.dense if use_pallas else ref.dense
    if use_pallas:
        def qact(h, bits, ibits):
            return k_quant.quantize_act(h, bits, ibits)
    else:
        def qact(h, bits, ibits):
            step = 2.0 ** (ibits - bits)
            return jnp.clip(jnp.round(h / step), 0.0, 2.0 ** bits - 1.0) * step

    x = qact(x, INPUT_BITS, INPUT_INT_BITS)
    h = x
    for name in ("conv1", "conv2"):
        prec = profile.layers()[name]
        h = conv(h, folded[name]["w"], folded[name]["b"])
        h = qact(h, prec.act_bits, prec.act_int_bits)
        h = pool(h)
    h = h.reshape(h.shape[0], -1)
    return dens(h, folded["dense"]["w"], folded["dense"]["b"])
