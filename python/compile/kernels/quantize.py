"""Pallas kernel: activation fake-quantization (the QONNX Quant node).

Elementwise VPU op: ReLU-clip + round onto the ufixed<bits,int_bits> grid.
Matches quant.quantize_act's forward semantics (no STE — inference only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, o_ref, *, step: float, qmax: float):
    x = x_ref[...]
    o_ref[...] = jnp.clip(jnp.round(x / step), 0.0, qmax) * step


def quantize_act(x: jnp.ndarray, bits: int, int_bits: int = 2) -> jnp.ndarray:
    """Unsigned fixed-point quantize with ReLU clip; matches quant.quantize_act
    forward. Works on any shape (treated as flat)."""
    step = 2.0 ** (int_bits - bits)
    qmax = 2.0 ** bits - 1.0
    shape = x.shape
    flat = x.reshape(-1)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, step=step, qmax=qmax),
        in_specs=[pl.BlockSpec(flat.shape, lambda: (0,))],
        out_specs=pl.BlockSpec(flat.shape, lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat)
    return out.reshape(shape)
