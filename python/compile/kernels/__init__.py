"""L1 Pallas kernels for the streaming CNN engine (interpret=True on CPU).

Public surface:
    conv2d.conv2d_3x3   -- 3x3 SAME conv, line-buffer->MXU schedule
    pool.maxpool2       -- 2x2 stride-2 max pool
    dense.dense         -- fully-connected head
    quantize.quantize_act -- QONNX Quant node (ReLU + fixed-point grid)
    ref.*               -- pure-jnp oracles for all of the above
"""

from . import conv2d, dense, pool, quantize, ref  # noqa: F401
