"""Pallas kernel: 2x2 stride-2 max pooling.

The FPGA template streams the pooling actor between conv blocks; on TPU the
pool is a cheap VPU reshape-max over the VMEM-resident block. Grid iterates
over the batch, one image per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, h: int, w: int, c: int):
    x = x_ref[0]                                     # (H, W, C)
    x = x.reshape(h // 2, 2, w // 2, 2, c)
    o_ref[0] = x.max(axis=(1, 3))


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 max pool. x: (N,H,W,C), H and W even. Matches ref.maxpool2."""
    n, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, "maxpool2 requires even spatial dims"
    return pl.pallas_call(
        functools.partial(_pool_kernel, h=h, w=w, c=c),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // 2, w // 2, c), jnp.float32),
        interpret=True,
    )(x)
