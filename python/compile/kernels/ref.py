"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has its semantics defined here with
plain jax.numpy / lax ops. pytest (python/tests/) asserts allclose between
the two across shapes, bit-widths, and random inputs — this is the L1
correctness signal.

All ops are NHWC, batch-leading. Convolutions are 3x3, stride 1, SAME
padding (the paper's tiny CNN uses 3x3 kernels; SAME keeps 28->28->14->14->7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_3x3(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """3x3 stride-1 SAME conv. x: (N,H,W,Cin), w: (3,3,Cin,Cout), b: (Cout,)."""
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 max pool. x: (N,H,W,C) with H,W even."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (N,F), w: (F,K), b: (K,)."""
    return x @ w + b


def im2col_3x3(x: jnp.ndarray) -> jnp.ndarray:
    """(N,H,W,C) -> (N, H*W, 9*C) patch matrix for SAME 3x3 conv.

    Column order matches kernels/conv2d.py and the rust dataflow simulator:
    (dy, dx, cin) row-major — i.e. patch[:, (dy*3+dx)*C + c].
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(xp[:, dy:dy + h, dx:dx + w, :])
    patches = jnp.concatenate(cols, axis=-1)          # (N,H,W,9C)
    return patches.reshape(n, h * w, 9 * c)


def conv2d_3x3_im2col(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Same as conv2d_3x3 but via the im2col + matmul schedule the Pallas
    kernel uses (and the FPGA line-buffer/MAC template computes)."""
    n, h, ww, c = x.shape
    cout = w.shape[-1]
    wm = w.reshape(9 * c, cout)                        # (dy,dx,cin) row-major
    out = im2col_3x3(x) @ wm + b                       # (N,H*W,Cout)
    return out.reshape(n, h, ww, cout)
