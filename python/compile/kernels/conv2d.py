"""Pallas kernel: 3x3 quantized convolution (line-buffer -> MXU schedule).

Hardware adaptation (DESIGN.md §5): the paper's FPGA convolutional actor is a
line buffer feeding a MAC array. On TPU the same insight — stream rows
through fast on-chip memory and keep the MAC array saturated — becomes: block
the activation stream through VMEM with BlockSpec (the line-buffer role) and
compute the window dot-products as one im2col-patch x weight-matrix matmul
(MXU-shaped: (H*W, 9*Cin) @ (9*Cin, Cout)) instead of a sliding scalar loop.

The grid iterates over the batch; each step holds one padded image, the
(9*Cin, Cout) weight matrix, and the (H*W, Cout) output block in VMEM:

    VMEM per step = (H+2)(W+2)Cin + 9*Cin*Cout + H*W*Cout floats
    (28x28x64 layer: ~0.9 MiB  << 16 MiB VMEM)

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel_im2col(xp_ref, w_ref, b_ref, o_ref, *, h: int, w: int, cin: int):
    """im2col schedule: materialize (N*H*W, 9*Cin) patches, one big matmul.

    MXU-preferred on real TPU (K = 9*Cin = 576 keeps the systolic array fed);
    costs an extra patch buffer in VMEM.
    """
    xp = xp_ref[...]                                  # (N, H+2, W+2, Cin)
    n = xp.shape[0]
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(xp[:, dy:dy + h, dx:dx + w, :])  # static slice
    patches = jnp.concatenate(cols, axis=-1)          # (N, H, W, 9*Cin)
    patches = patches.reshape(n * h * w, 9 * cin)
    acc = jnp.dot(patches, w_ref[...],
                  preferred_element_type=jnp.float32)  # MXU matmul
    o_ref[...] = acc + b_ref[...]


def _conv_kernel_acc(xp_ref, w_ref, b_ref, o_ref, *, h: int, w: int, cin: int,
                     cout: int):
    """Tap-accumulation schedule: nine (N*H*W, Cin) x (Cin, Cout) matmuls,
    no patch buffer — the nine unrolled line-buffer taps accumulate in
    place, exactly like the FPGA MAC array walks the window.

    §Perf (EXPERIMENTS.md): 2.2x faster than im2col under interpret=True on
    CPU PJRT (no 3.6 MiB patch materialization); on real TPU im2col's wider
    K dimension is preferred — select with schedule="im2col".
    """
    xp = xp_ref[...]                                  # (N, H+2, W+2, Cin)
    n = xp.shape[0]
    acc = jnp.zeros((n * h * w, cout), jnp.float32) + b_ref[...]
    for dy in range(3):
        for dx in range(3):
            tap = xp[:, dy:dy + h, dx:dx + w, :].reshape(n * h * w, cin)
            wt = w_ref[(dy * 3 + dx) * cin:(dy * 3 + dx + 1) * cin, :]
            acc = acc + jnp.dot(tap, wt, preferred_element_type=jnp.float32)
    o_ref[...] = acc


def conv2d_3x3(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               schedule: str = "acc") -> jnp.ndarray:
    """3x3 stride-1 SAME conv via Pallas. Matches ref.conv2d_3x3.

    x: (N,H,W,Cin) float32, w: (3,3,Cin,Cout), b: (Cout,) -> (N,H,W,Cout).
    schedule: "acc" (tap accumulation, CPU/interpret-fast, default) or
    "im2col" (single wide matmul, MXU-preferred on real TPU).

    VMEM budget (worst layer, conv2 @ batch 8): padded input 0.5 MiB +
    weights 0.15 MiB + accumulator 0.4 MiB (< 1.1 MiB; im2col adds a
    3.6 MiB patch buffer) << 16 MiB.
    """
    n, h, ww, cin = x.shape
    cout = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    wm = w.reshape(9 * cin, cout)                    # (dy,dx,cin) row-major

    if schedule == "acc":
        kernel = functools.partial(_conv_kernel_acc, h=h, w=ww, cin=cin,
                                   cout=cout)
    elif schedule == "im2col":
        kernel = functools.partial(_conv_kernel_im2col, h=h, w=ww, cin=cin)
    else:
        raise ValueError(f"unknown conv schedule '{schedule}'")

    out = pl.pallas_call(
        kernel,
        in_specs=[
            # Whole padded batch resident in VMEM (the "line buffer" role).
            pl.BlockSpec((n, h + 2, ww + 2, cin), lambda: (0, 0, 0, 0)),
            pl.BlockSpec((9 * cin, cout), lambda: (0, 0)),
            pl.BlockSpec((cout,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n * h * ww, cout), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n * h * ww, cout), jnp.float32),
        interpret=True,
    )(xp, wm, b)
    return out.reshape(n, h, ww, cout)
