"""Pallas kernel: fully-connected (Gemm) layer.

One MXU-shaped matmul; the whole batch block lives in VMEM (the classifier
head is tiny: F=3136, K=10).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32) + b_ref[...]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (N,F) @ w: (F,K) + b: (K,). Matches ref.dense."""
    n, f = x.shape
    k = w.shape[-1]
    return pl.pallas_call(
        _dense_kernel,
        in_specs=[
            pl.BlockSpec((n, f), lambda: (0, 0)),
            pl.BlockSpec((f, k), lambda: (0, 0)),
            pl.BlockSpec((k,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, w, b)
