"""Integer-exact inference pipeline (the spec for the rust dataflow engine).

This module quantizes a folded model into pure-integer form (TFLite-style)
and runs it with exact integer arithmetic (f64 matmuls — every intermediate
is < 2^53 so BLAS f64 is bit-exact integer math, see the bound analysis in
DESIGN.md). The rust `dataflow` module implements the *same* pipeline with
i64 accumulators; test vectors exported by `export.py` pin the two together
bit-for-bit.

Integer pipeline per conv layer:
    acc_c  = sum(qx * qw_c) + qb_c                    (i64; qb at scale sx*sw_c)
    qy_c   = clamp((acc_c * M_c + 2^(sh_c-1)) >> sh_c, 0, 2^act_bits - 1)
where (M_c, sh_c) is the fixed-point encoding of sx * sw_c / sy
(requantization with fused ReLU via the clamp-at-0).
Max-pool operates directly on codes (monotone). The dense layer emits raw
i64 accumulators as logits (argmax-equivalent: per-tensor positive scale).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import quant
from .profiles import INPUT_BITS, INPUT_INT_BITS, Profile


@dataclass
class IntConv:
    """Quantized conv layer: integer codes + per-channel requant."""
    w_codes: np.ndarray        # (3,3,Cin,Cout) int32
    b_codes: np.ndarray        # (Cout,) int64 — at scale sx*sw_c
    mult: np.ndarray           # (Cout,) int64 requant multiplier
    shift: np.ndarray          # (Cout,) int64 right shift
    act_bits: int
    weight_bits: int
    # Bookkeeping for export / power model:
    w_step: np.ndarray = field(default=None)   # (Cout,) float
    in_step: float = 0.0
    out_step: float = 0.0


@dataclass
class IntDense:
    w_codes: np.ndarray        # (F,K) int32
    b_codes: np.ndarray        # (K,) int64 — at scale sx*sw
    weight_bits: int
    w_step: float = 0.0
    in_step: float = 0.0


@dataclass
class IntModel:
    profile_name: str
    conv1: IntConv
    conv2: IntConv
    dense: IntDense


def _quantize_conv(w, b, gamma, beta, mean, var, prec, in_step: float,
                   bn_eps: float) -> IntConv:
    """Quantize one conv+BN block to integer form.

    QAT quantizes W on the fixed po2 grid BEFORE BN, so the integer codes
    are exactly `weight_codes(W)`; the per-channel BN gain g moves into the
    requantization scale (sign(g) is absorbed into the codes so the
    multiplier stays non-negative):

        real_out_c = g_c * (acc * sx * sw) + (g_c*b_c + t_c)
        qy_c = clamp(round((acc' + qb_c) * |g_c|*sx*sw / sy), 0, qmax)
        with acc' = acc * sign(g_c),  qb_c = round((g_c*b_c+t_c)/(|g_c|*sx*sw))
    """
    w = np.asarray(w, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    g = np.asarray(gamma, np.float64) / np.sqrt(np.asarray(var, np.float64) + bn_eps)
    t = np.asarray(beta, np.float64) - g * np.asarray(mean, np.float64)

    w_codes = quant.weight_codes(w, prec.weight_bits)      # fixed grid
    sw = quant.weight_step(prec.weight_bits)
    sign = np.where(g < 0, -1, 1).astype(np.int32)
    w_codes = w_codes * sign[None, None, None, :]
    g_abs = np.maximum(np.abs(g), 1e-12)

    out_step = quant.act_step(prec.act_bits, prec.act_int_bits)
    acc_scale = g_abs * in_step * sw                       # (Cout,) >= 0
    b_codes = np.round((g * b + t) / acc_scale).astype(np.int64)
    cout = w.shape[-1]
    mult = np.empty(cout, dtype=np.int64)
    shift = np.empty(cout, dtype=np.int64)
    for c in range(cout):
        m, s = quant.requant_multiplier(acc_scale[c] / out_step)
        mult[c], shift[c] = m, s
    return IntConv(w_codes.astype(np.int32), b_codes, mult, shift,
                   prec.act_bits, prec.weight_bits,
                   w_step=g_abs * sw, in_step=in_step, out_step=out_step)


def quantize_model(params, state, profile: Profile, bn_eps: float = 1e-3) -> IntModel:
    """Trained params + BN state -> fully-integer model for `profile`."""
    in_step = quant.act_step(INPUT_BITS, INPUT_INT_BITS)    # 1/256
    c1 = _quantize_conv(
        params["conv1"]["w"], params["conv1"]["b"],
        params["bn1"]["gamma"], params["bn1"]["beta"],
        state["bn1"]["mean"], state["bn1"]["var"],
        profile.conv1, in_step, bn_eps)
    c2 = _quantize_conv(
        params["conv2"]["w"], params["conv2"]["b"],
        params["bn2"]["gamma"], params["bn2"]["beta"],
        state["bn2"]["mean"], state["bn2"]["var"],
        profile.conv2, c1.out_step, bn_eps)
    wd = np.asarray(params["dense"]["w"], dtype=np.float64)
    bd = np.asarray(params["dense"]["b"], dtype=np.float64)
    wd_codes = quant.weight_codes(wd, profile.dense.weight_bits)
    wd_step = quant.weight_step(profile.dense.weight_bits)
    bd_codes = np.round(bd / (c2.out_step * wd_step)).astype(np.int64)
    dn = IntDense(wd_codes.astype(np.int32), bd_codes,
                  profile.dense.weight_bits, w_step=float(wd_step),
                  in_step=c2.out_step)
    return IntModel(profile.name, c1, c2, dn)


# ---------------------------------------------------------------------------
# Exact integer execution (f64 matmul == exact integer math within 2^53).
# ---------------------------------------------------------------------------

def _im2col(x: np.ndarray) -> np.ndarray:
    """(N,H,W,C) int -> (N,H*W,9C) f64, column order (dy,dx,cin)."""
    n, h, w, c = x.shape
    xp = np.zeros((n, h + 2, w + 2, c), dtype=np.float64)
    xp[:, 1:-1, 1:-1, :] = x
    cols = [xp[:, dy:dy + h, dx:dx + w, :]
            for dy in range(3) for dx in range(3)]
    return np.concatenate(cols, axis=-1).reshape(n, h * w, 9 * c)


def conv_layer(x_codes: np.ndarray, layer: IntConv) -> np.ndarray:
    """x_codes: (N,H,W,Cin) nonneg int -> (N,H,W,Cout) codes in [0, 2^ab-1]."""
    n, h, w, cin = x_codes.shape
    cout = layer.w_codes.shape[-1]
    wm = layer.w_codes.reshape(9 * cin, cout).astype(np.float64)
    acc = _im2col(x_codes) @ wm                       # exact in f64
    acc = acc.reshape(n, h, w, cout) + layer.b_codes.astype(np.float64)
    acc = acc.astype(np.int64)
    # requant: (acc * M + 2^(sh-1)) >> sh, clamp to [0, qmax]
    m = layer.mult[None, None, None, :]
    sh = layer.shift[None, None, None, :]
    half = np.where(sh > 0, np.int64(1) << np.maximum(sh - 1, 0), np.int64(0))
    prod = acc * m + half
    q = prod >> sh
    qmax = (1 << layer.act_bits) - 1
    return np.clip(q, 0, qmax).astype(np.int64)


def maxpool2(x_codes: np.ndarray) -> np.ndarray:
    n, h, w, c = x_codes.shape
    return x_codes.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def dense_layer(x_codes: np.ndarray, layer: IntDense) -> np.ndarray:
    """x: (N,F) codes -> (N,K) i64 logits (raw accumulators)."""
    acc = x_codes.astype(np.float64) @ layer.w_codes.astype(np.float64)
    return acc.astype(np.int64) + layer.b_codes[None, :]


def run(model: IntModel, x_u8: np.ndarray) -> np.ndarray:
    """x_u8: (N,28,28,1) u8 input codes -> (N,10) i64 logits."""
    h = conv_layer(x_u8.astype(np.int64), model.conv1)
    h = maxpool2(h)
    h = conv_layer(h, model.conv2)
    h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    return dense_layer(h, model.dense)


def accuracy(model: IntModel, x_u8: np.ndarray, labels: np.ndarray,
             batch: int = 256) -> float:
    correct = 0
    for i in range(0, len(labels), batch):
        logits = run(model, x_u8[i:i + batch])
        correct += int((logits.argmax(axis=1) == labels[i:i + batch]).sum())
    return correct / len(labels)
