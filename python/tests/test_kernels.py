"""L1 correctness: Pallas kernels vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import conv2d, dense, pool, quantize, ref
from compile import quant

SETTINGS = dict(max_examples=25, deadline=None)


def arr(rng, shape, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    h=st.integers(2, 10),
    w=st.integers(2, 10),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, h, w, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (n, h, w, cin))
    wgt = arr(rng, (3, 3, cin, cout))
    b = arr(rng, (cout,))
    got = conv2d.conv2d_3x3(x, wgt, b)
    want = ref.conv2d_3x3(x, wgt, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    h=st.integers(1, 8),
    w=st.integers(1, 8),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(n, h, w, c, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (n, 2 * h, 2 * w, c))
    np.testing.assert_allclose(pool.maxpool2(x), ref.maxpool2(x))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    f=st.integers(1, 64),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(n, f, k, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (n, f))
    wgt = arr(rng, (f, k))
    b = arr(rng, (k,))
    np.testing.assert_allclose(
        dense.dense(x, wgt, b), ref.dense(x, wgt, b), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 4, 8, 16]),
    int_bits=st.sampled_from([0, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_act_matches_quant(bits, int_bits, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (4, 17), lo=-5.0, hi=5.0)
    got = quantize.quantize_act(x, bits, int_bits)
    want = quant.quantize_act(x, bits, int_bits)
    np.testing.assert_allclose(got, want)


def test_conv_schedules_agree():
    rng = np.random.default_rng(5)
    x = arr(rng, (2, 6, 6, 4))
    w = arr(rng, (3, 3, 4, 5))
    b = arr(rng, (5,))
    a = conv2d.conv2d_3x3(x, w, b, schedule="acc")
    i = conv2d.conv2d_3x3(x, w, b, schedule="im2col")
    r = ref.conv2d_3x3(x, w, b)
    np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(i, r, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        conv2d.conv2d_3x3(x, w, b, schedule="bogus")


def test_conv_im2col_equals_direct():
    rng = np.random.default_rng(0)
    x = arr(rng, (2, 6, 5, 3))
    w = arr(rng, (3, 3, 3, 4))
    b = arr(rng, (4,))
    np.testing.assert_allclose(
        ref.conv2d_3x3_im2col(x, w, b), ref.conv2d_3x3(x, w, b),
        rtol=1e-5, atol=1e-5,
    )


def test_quantize_act_idempotent():
    rng = np.random.default_rng(1)
    x = arr(rng, (3, 9))
    q1 = quant.quantize_act(x, 8, 2)
    q2 = quant.quantize_act(q1, 8, 2)
    np.testing.assert_allclose(q1, q2)


def test_quantize_weight_on_grid():
    rng = np.random.default_rng(2)
    w = arr(rng, (3, 3, 2, 4), lo=-1.5, hi=1.5)
    for bits in (4, 8):
        q = np.asarray(quant.quantize_weight(jnp.asarray(w), bits))
        step = quant.weight_step(bits)
        codes = q / step
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-6)
        assert np.abs(codes).max() <= 2 ** (bits - 1) - 1


def test_requant_multiplier_approximates_scale():
    for scale in (1e-4, 0.037, 0.5, 1.0, 7.3):
        m, sh = quant.requant_multiplier(scale)
        for acc in (0, 1, 17, 1000, 123456):
            want = acc * scale
            got = (acc * m + (1 << (sh - 1) if sh > 0 else 0)) >> sh
            assert abs(got - want) <= max(1.0, abs(want) * 1e-3), (
                scale, acc, got, want)
