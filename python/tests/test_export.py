"""Artifact-contract tests: the exported QONNX JSON / eval / vectors /
testset that the rust side consumes. Skipped when `make artifacts` has not
run (unit correctness does not depend on them)."""

import json
import os

import numpy as np
import pytest

from compile import intref, quant
from compile.profiles import ALL, BY_NAME

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "model_A8-W8.qonnx.json")),
    reason="artifacts not built",
)


def load(name):
    with open(os.path.join(ART, name)) as f:
        return json.load(f)


@pytest.mark.parametrize("profile", [p.name for p in ALL])
def test_qonnx_schema_complete(profile):
    doc = load(f"model_{profile}.qonnx.json")
    assert doc["qonnx_version"] == 1
    assert doc["profile"] == profile
    ops = [n["op"] for n in doc["nodes"]]
    assert ops == ["QConv2d", "MaxPool2", "QConv2d", "MaxPool2", "Flatten", "QGemm"]
    spec = BY_NAME[profile]
    conv1, conv2 = doc["nodes"][0], doc["nodes"][2]
    assert conv1["attrs"]["weight_bits"] == spec.conv1.weight_bits
    assert conv1["attrs"]["act_bits"] == spec.conv1.act_bits
    assert conv2["attrs"]["weight_bits"] == spec.conv2.weight_bits
    assert conv2["attrs"]["act_bits"] == spec.conv2.act_bits
    # weight codes within declared range, requant sane
    for node in (conv1, conv2):
        bits = node["attrs"]["weight_bits"]
        qmax = 2 ** (bits - 1) - 1
        codes = np.array(node["weights"]["w_codes"])
        assert np.abs(codes).max() <= qmax
        assert all(0 <= s <= 62 for s in node["weights"]["shift"])
        assert all(0 <= m < 2**20 for m in node["weights"]["mult"])


@pytest.mark.parametrize("profile", [p.name for p in ALL])
def test_vectors_consistent_with_eval(profile):
    vec = load(f"vectors_{profile}.json")
    ev = load(f"eval_{profile}.json")
    assert vec["profile"] == profile == ev["profile"]
    logits = np.array(vec["logits"])
    assert logits.shape == (vec["n"], 10)
    assert (logits.argmax(axis=1) == np.array(vec["pred"])).all()
    assert 0.5 < ev["int_accuracy"] <= 1.0


def test_testset_binary_matches_meta():
    meta = load("testset.json")
    raw = open(os.path.join(ART, "testset.bin"), "rb").read()
    assert len(raw) == meta["n"] * meta["height"] * meta["width"] * meta["channels"]
    assert len(meta["labels"]) == meta["n"]
    assert set(meta["labels"]) <= set(range(10))


def test_mixed_shares_outer_layers_with_a8w8():
    """Sect. 4.3 contract: Mixed's conv1/dense integer weights are identical
    to A8-W8's (frozen during fine-tuning) — this is what lets MDC share
    their actors AND weight ROMs in the adaptive engine."""
    a = load("model_A8-W8.qonnx.json")
    m = load("model_Mixed.qonnx.json")
    assert a["nodes"][0]["weights"]["w_codes"] == m["nodes"][0]["weights"]["w_codes"]
    assert a["nodes"][5]["weights"]["w_codes"] == m["nodes"][5]["weights"]["w_codes"]
    # and the inner conv genuinely differs (different precision)
    assert a["nodes"][2]["attrs"]["weight_bits"] == 8
    assert m["nodes"][2]["attrs"]["weight_bits"] == 4


def test_eval_table_has_paper_shape():
    evals = {p.name: load(f"eval_{p.name}.json")["int_accuracy"] for p in ALL}
    w8_min = min(evals["A16-W8"], evals["A8-W8"])
    w4_max = max(evals["A16-W4"], evals["A8-W4"], evals["A4-W4"])
    assert w8_min > w4_max, f"W8 {w8_min} not above W4 {w4_max}"
    assert evals["Mixed"] <= evals["A8-W8"]
    assert evals["Mixed"] > w4_max


def test_hlo_artifacts_have_full_constants():
    """Regression: HLO text must not elide large constants ({...}) — the
    rust loader would silently compile garbage weights."""
    for profile in [p.name for p in ALL]:
        for suffix in ("", "_b8"):
            path = os.path.join(ART, f"model_{profile}{suffix}.hlo.txt")
            text = open(path).read()
            assert "{...}" not in text, f"{path} has elided constants"
            assert "ENTRY" in text


def test_requant_multiplier_edge_cases():
    assert quant.requant_multiplier(0.0) == (0, 0)
    m, s = quant.requant_multiplier(1.0)
    assert (1 << s) == m * 1  # exact power of two representation
    # tiny scale keeps shift in range after clamping
    m, s = quant.requant_multiplier(1e-9)
    assert m >= 0 and s >= 0
