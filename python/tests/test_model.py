"""L2 correctness: model shapes, BN folding, QAT-vs-inference agreement,
and the integer pipeline (intref) vs the float inference graph."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import dataset, intref, model, quant
from compile.profiles import ALL, BY_NAME, Profile, LayerPrec


@pytest.fixture(scope="module")
def tiny_setup():
    params = model.init_params(0)
    state = model.init_bn_state()
    # push BN stats away from init so folding is non-trivial
    state["bn1"]["mean"] = jnp.linspace(-0.5, 0.5, model.CONV_FILTERS)
    state["bn1"]["var"] = jnp.linspace(0.5, 2.0, model.CONV_FILTERS)
    state["bn2"]["mean"] = jnp.linspace(-0.2, 0.8, model.CONV_FILTERS)
    state["bn2"]["var"] = jnp.linspace(0.3, 1.5, model.CONV_FILTERS)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, size=(4, 28, 28, 1)).astype(np.float32))
    return params, state, x


def test_qat_forward_shapes(tiny_setup):
    params, state, x = tiny_setup
    profile = BY_NAME["A8-W8"]
    logits, new_state = model.qat_forward(params, state, x, profile, train=True)
    assert logits.shape == (4, 10)
    assert new_state["bn1"]["mean"].shape == (model.CONV_FILTERS,)
    # eval mode does not change state
    _, st2 = model.qat_forward(params, state, x, profile, train=False)
    np.testing.assert_allclose(st2["bn1"]["mean"], state["bn1"]["mean"])


def test_fold_bn_preserves_inference(tiny_setup):
    """Folded inference graph == QAT eval graph up to quantization-boundary
    rounding: float re-association (g*(conv+b)+t vs conv(g*W)+(g*b+t)) can
    flip values sitting exactly on a grid boundary by one step, so we allow
    a few activation steps of slack and require identical predictions."""
    params, state, x = tiny_setup
    for name in ("A8-W8", "A4-W4", "Mixed"):
        profile = BY_NAME[name]
        want, _ = model.qat_forward(params, state, x, profile, train=False)
        folded = model.fold_bn(params, state, profile)
        got = model.infer_float(folded, x, profile, use_pallas=False)
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.08)
        assert (np.asarray(got).argmax(1) == np.asarray(want).argmax(1)).all()


def test_pallas_inference_matches_jnp(tiny_setup):
    params, state, x = tiny_setup
    profile = BY_NAME["A8-W4"]
    folded = model.fold_bn(params, state, profile)
    a = model.infer_float(folded, x, profile, use_pallas=True)
    b = model.infer_float(folded, x, profile, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_intref_argmax_matches_float(tiny_setup):
    """Integer pipeline and float inference agree on predictions."""
    params, state, x = tiny_setup
    profile = BY_NAME["A8-W8"]
    im = intref.quantize_model(params, state, profile, bn_eps=model.BN_EPS)
    codes = dataset.input_codes(np.asarray(x))
    int_logits = intref.run(im, codes)
    folded = model.fold_bn(params, state, profile)
    xq = jnp.asarray(codes.astype(np.float32) / 256.0)
    float_logits = model.infer_float(folded, xq, profile, use_pallas=False)
    assert (int_logits.argmax(1) == np.asarray(float_logits).argmax(1)).all()


def test_intref_weight_codes_within_range(tiny_setup):
    params, state, _ = tiny_setup
    for p in ALL:
        im = intref.quantize_model(params, state, p, bn_eps=model.BN_EPS)
        for layer, bits in ((im.conv1, p.conv1.weight_bits),
                            (im.conv2, p.conv2.weight_bits),
                            (im.dense, p.dense.weight_bits)):
            qmax = 2 ** (bits - 1) - 1
            assert np.abs(layer.w_codes).max() <= qmax


def test_intref_requant_range(tiny_setup):
    params, state, x = tiny_setup
    profile = BY_NAME["A4-W4"]
    im = intref.quantize_model(params, state, profile, bn_eps=model.BN_EPS)
    codes = dataset.input_codes(np.asarray(x))
    h = intref.conv_layer(codes.astype(np.int64), im.conv1)
    assert h.min() >= 0
    assert h.max() <= 2 ** im.conv1.act_bits - 1


def test_dataset_deterministic_and_bounded():
    x1, y1, xt1, yt1 = dataset.make_dataset(64, 16, seed=7)
    x2, y2, _, _ = dataset.make_dataset(64, 16, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 28, 28, 1)
    assert x1.min() >= 0.0 and x1.max() < 1.0
    assert set(np.unique(y1)) <= set(range(10))


def test_dataset_input_codes_roundtrip():
    x, _, _, _ = dataset.make_dataset(8, 2, seed=3)
    codes = dataset.input_codes(x)
    assert codes.dtype == np.uint8
    q = dataset.quantize_input(x)
    np.testing.assert_allclose(q, codes.astype(np.float32) / 256.0)


def test_profiles_table():
    assert [p.name for p in ALL] == [
        "A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed"]
    mixed = BY_NAME["Mixed"]
    assert mixed.conv1 == LayerPrec(8, 8)
    assert mixed.conv2 == LayerPrec(4, 4)
    assert mixed.dense == LayerPrec(8, 8)
